"""Kernel micro-benchmarks.

CPU wall-times of interpret-mode Pallas are NOT TPU numbers; the meaningful
TPU-facing output is the derived column: HBM bytes per search stage
(naive re-read vs fused one-pass) and weight bytes per matmul (bf16 vs fp8)
— the roofline quantities the kernels exist to move.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call, write_bench


def bench_scale_search() -> list[dict]:
    from repro.configs import QuantConfig
    from repro.core.search import search_scale
    from repro.kernels.scale_search import ops as K

    I = O = 1024
    key = jax.random.PRNGKey(0)
    wb = jax.random.normal(key, (I, O)) * 0.05
    wp = wb + jax.random.normal(jax.random.PRNGKey(1), (I, O)) * 0.002
    alphas = jnp.linspace(0.8, 1.25, 16)

    n_cand = alphas.shape[0]
    bytes_naive = (2 * I * O * 4) * (n_cand + 1)   # wp+wb re-read per cand
    bytes_fused = 2 * I * O * 4 + n_cand * 8 * 4 * (I // 128) * (O // 128)
    derived = (f"hbm_bytes naive={bytes_naive/1e6:.1f}MB "
               f"fused={bytes_fused/1e6:.1f}MB "
               f"reduction={bytes_naive/bytes_fused:.1f}x")

    rows = []
    # wall-time of the jnp reference sweep (the compute itself)
    us = time_call(lambda: K.sweep(wp, wb, alphas, block_size=128,
                                   use_kernel=False))
    rows.append(emit("scale_search.sweep_ref_1024x1024x16cand", us, derived))

    q = QuantConfig(metric="sign", granularity="block")
    us = time_call(lambda: search_scale(wp, wb, q))
    rows.append(emit("scale_search.alg1_naive_1024x1024", us,
                     "paper Alg.1, 5+10 cand"))
    return rows


def bench_fp8_matmul() -> list[dict]:
    from repro.kernels.fp8_matmul.ref import matmul_fp8_ref
    from repro.kernels.fp8_quant.ops import quantize_fp8

    M, K, N = 128, 1024, 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    q, s = quantize_fp8(w)
    wbf = w.astype(jnp.bfloat16)

    derived = (f"weight_bytes bf16={K*N*2/1e6:.1f}MB fp8={K*N/1e6:.1f}MB "
               f"decode_roofline=2.0x")
    rows = []
    us = time_call(jax.jit(lambda x, q, s: matmul_fp8_ref(x, q, s)), x, q, s)
    rows.append(emit("fp8_matmul.dequant_ref_128x1024x1024", us, derived))
    us = time_call(jax.jit(lambda x, w: x @ w), x, wbf)
    rows.append(emit("fp8_matmul.bf16_dense_128x1024x1024", us, ""))
    return rows


def bench_quantize_tree() -> list[dict]:
    from repro.configs import QuantConfig
    from repro.quantize import quantize

    key = jax.random.PRNGKey(0)
    base = {"l": {"w1": jax.random.normal(key, (8, 256, 256)) * 0.05,
                  "w2": jax.random.normal(key, (8, 256, 512)) * 0.05}}
    post = jax.tree.map(
        lambda p: p + 0.002 * jax.random.normal(jax.random.PRNGKey(1),
                                                p.shape), base)
    q = QuantConfig(method="daq", metric="sign", granularity="block")
    us = time_call(lambda: quantize(post, base, q)[0])
    n = sum(x.size for x in jax.tree.leaves(post))
    return [emit("daq.quantize_tree_1.6Mparam", us, f"params={n}")]


def main() -> None:
    rows = bench_scale_search() + bench_fp8_matmul() + bench_quantize_tree()
    write_bench("BENCH_kernels.json", rows,
                workload={"suite": "kernels",
                          "cases": [r["name"] for r in rows]})


if __name__ == "__main__":
    main()
