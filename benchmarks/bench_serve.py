"""Serving bench: legacy host-scheduled loop vs device-resident engine.

Races the two continuous batchers on identical greedy workloads (reduced
arch, CPU-scale) and reports tok/s plus host syncs per generated token —
the metric the engine exists to crush (the old loop blocks once per slot
per token; the engine once per K decode steps).

  PYTHONPATH=src python -m benchmarks.bench_serve [--gen 24 --k-steps 8 ...]
  PYTHONPATH=src python -m benchmarks.run serve     # same, CSV + JSON

Writes ``BENCH_serve.json`` and prints ``benchmarks.common.emit`` CSV rows.
Each loop is run twice; the second (warm-jit) run is timed.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import emit
from repro.configs import get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine, serve_host_loop
from repro.models import build_model


def _timed(fn):
    fn()                      # warm the jit caches
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(arch: str = "glm4-9b", requests: int = 8, batch: int = 2,
        prompt_len: int = 16, gen: int = 24, k_steps: int = 8,
        out_path: str = "BENCH_serve.json") -> dict:
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = LanguageSpec(vocab=cfg.vocab_size)
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, prompt_len)[0]
               for i in range(requests)]
    cache_len = prompt_len + gen + 9

    (old_outs, old_stats), old_dt = _timed(lambda: serve_host_loop(
        model, params, prompts, batch=batch, gen_tokens=gen,
        cache_len=cache_len, return_stats=True))

    eng = Engine(model, params, slots=batch, cache_len=cache_len,
                 k_steps=k_steps)
    (eng_outs, eng_stats), eng_dt = _timed(lambda: eng.serve(
        prompts, gen_tokens=gen, return_stats=True))

    if eng_outs != old_outs:
        print("bench_serve: WARNING: engine outputs differ from the host "
              "loop (greedy parity violated)", flush=True)

    def row(name, dt, stats):
        tok = stats["tokens"]
        return {"tok_per_s": tok / dt, "wall_s": dt, "tokens": tok,
                "host_syncs": stats["host_syncs"],
                "host_syncs_per_token": stats["host_syncs"] / tok,
                "prefill_calls": stats["prefill_calls"],
                "dispatches": stats["dispatches"]}

    result = {
        "workload": {"arch": arch, "requests": requests, "batch": batch,
                     "prompt_len": prompt_len, "gen": gen,
                     "k_steps": k_steps, "greedy_parity":
                     eng_outs == old_outs},
        "old": row("old", old_dt, old_stats),
        "engine": row("engine", eng_dt, eng_stats),
    }
    result["speedup"] = (result["engine"]["tok_per_s"]
                         / result["old"]["tok_per_s"])
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    emit("serve.old_host_loop", old_dt * 1e6,
         f"tok_per_s={result['old']['tok_per_s']:.1f};"
         f"syncs_per_tok={result['old']['host_syncs_per_token']:.3f}")
    emit("serve.engine", eng_dt * 1e6,
         f"tok_per_s={result['engine']['tok_per_s']:.1f};"
         f"syncs_per_tok={result['engine']['host_syncs_per_token']:.3f}")
    emit("serve.speedup", 0, f"x={result['speedup']:.2f}")
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--k-steps", type=int, default=8)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(args.arch, args.requests, args.batch, args.prompt_len, args.gen,
        args.k_steps, args.out)


if __name__ == "__main__":
    main()
