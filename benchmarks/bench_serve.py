"""Serving bench: legacy host loop vs contiguous vs paged vs paged+prefix.

Four workloads, each run greedy and parity-checked token-for-token:

* **uniform** — every request has the same prompt length (the contiguous
  cache's best case).  Races the legacy host-scheduled loop against the
  device-resident engine (host syncs per token — the PR 2 metric) and the
  paged engine at capacity parity (pool = slots * ceil(cap/bs) blocks), so
  any block-table gather overhead shows up as a tok/s delta.
* **mixed** — prompt lengths spread ~8x.  The contiguous cache must size
  every slot for the longest admissible request; the paged pool is sized to
  the workload's actual concurrent need (sum of the ``slots`` largest
  per-request reservations), so ``cache_bytes`` drops roughly by the
  longest/typical length ratio while outputs stay token-exact.
* **shared-prefix** — requests share a long common system prompt (the
  production shape).  Contiguous vs paged vs paged+prefix-cache: the
  prefix engine prefills strictly fewer prompt tokens (matched blocks are
  mapped, not recomputed) at token-exact outputs; ``prefill_tokens`` is
  the headline column.
* **longprompt** — a long-prompt request arrives while short requests
  decode (the chunked-prefill motivation): paged one-shot admission vs
  paged+chunked, tok/s and prefill tokens recorded.

  PYTHONPATH=src python -m benchmarks.bench_serve [--gen 24 --k-steps 8 ...]
  PYTHONPATH=src python -m benchmarks.run serve     # same, CSV + JSON

Writes ``BENCH_serve.json`` and prints ``benchmarks.common.emit`` CSV rows.
Each loop is run twice; the second (warm-jit) run is timed.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_meta
from repro.configs import get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine, blocks_for, serve_host_loop
from repro.models import build_model


def _race(fns: dict, repeats: int = 3) -> dict:
    """Time competing serve loops fairly: warm every jit cache first, then
    round-robin the timed repeats (best-of-N per loop) so slow host-load
    drift hits all contenders equally instead of whichever ran last."""
    outs = {name: fn() for name, fn in fns.items()}      # warm
    best = {name: float("inf") for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            outs[name] = fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: (outs[name], best[name]) for name in fns}


def _row(dt, stats):
    tok = stats["tokens"]
    return {"tok_per_s": tok / dt, "wall_s": dt, "tokens": tok,
            "host_syncs": stats["host_syncs"],
            "host_syncs_per_token": stats["host_syncs"] / tok,
            "prefill_calls": stats["prefill_calls"],
            "prefill_tokens": stats.get("prefill_tokens", 0),
            "dispatches": stats["dispatches"],
            "cache_bytes": stats.get("cache_bytes", 0)}


def run(arch: str = "glm4-9b", requests: int = 8, batch: int = 4,
        prompt_len: int = 16, gen: int = 24, k_steps: int = 8,
        block_size: int = 8, out_path: str = "BENCH_serve.json") -> dict:
    from repro.telemetry import MetricsRegistry
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = LanguageSpec(vocab=cfg.vocab_size)
    # request-lifecycle metrics from the observability-rich engines
    # (prefix-cached + chunked) ride the artifact via run_meta(metrics=)
    reg = MetricsRegistry()

    # ---- uniform workload --------------------------------------------------
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, prompt_len)[0]
               for i in range(requests)]
    cache_len = prompt_len + gen + 8   # block-aligned for the default --block-size

    eng = Engine(model, params, slots=batch, cache_len=cache_len,
                 k_steps=k_steps)
    peng = Engine(model, params, slots=batch, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=block_size)
    raced = _race({
        "old": lambda: serve_host_loop(
            model, params, prompts, batch=batch, gen_tokens=gen,
            cache_len=cache_len, return_stats=True),
        "engine": lambda: eng.serve(prompts, gen_tokens=gen,
                                    return_stats=True),
        "paged": lambda: peng.serve(prompts, gen_tokens=gen,
                                    return_stats=True),
    })
    (old_outs, old_stats), old_dt = raced["old"]
    (eng_outs, eng_stats), eng_dt = raced["engine"]
    (pag_outs, pag_stats), pag_dt = raced["paged"]

    parity = eng_outs == old_outs and pag_outs == eng_outs
    if not parity:
        print("bench_serve: WARNING: engine outputs differ (greedy parity "
              "violated)", flush=True)

    # ---- mixed-length workload --------------------------------------------
    spread = [max(4, prompt_len // 2), prompt_len * 4, prompt_len,
              prompt_len * 2, max(4, prompt_len // 2), prompt_len * 3,
              prompt_len, prompt_len]
    mixed_lens = [spread[i % len(spread)] for i in range(requests)]
    mixed = [sample_batch(jax.random.PRNGKey(100 + i), spec, 1, L)[0]
             for i, L in enumerate(mixed_lens)]
    mixed_cache_len = max(mixed_lens) + gen + 8   # contiguous: worst case
    # paged pool: the `batch` largest concurrent reservations
    needs = sorted((blocks_for(L + gen - 1, block_size)
                    for L in mixed_lens), reverse=True)
    num_blocks = sum(needs[:batch])

    meng = Engine(model, params, slots=batch, cache_len=mixed_cache_len,
                  k_steps=k_steps)
    mpag = Engine(model, params, slots=batch, cache_len=mixed_cache_len,
                  k_steps=k_steps, paged=True, block_size=block_size,
                  num_blocks=num_blocks)
    mraced = _race({
        "engine": lambda: meng.serve(mixed, gen_tokens=gen,
                                     return_stats=True),
        "paged": lambda: mpag.serve(mixed, gen_tokens=gen,
                                    return_stats=True),
    })
    (m_eng_outs, m_eng_stats), m_eng_dt = mraced["engine"]
    (m_pag_outs, m_pag_stats), m_pag_dt = mraced["paged"]

    mixed_parity = m_pag_outs == m_eng_outs
    if not mixed_parity:
        print("bench_serve: WARNING: paged outputs differ on the mixed "
              "workload (greedy parity violated)", flush=True)

    # ---- shared-system-prompt workload -------------------------------------
    # 16 requests sharing a long common prefix (production traffic shape):
    # the prefix cache maps matched blocks instead of recomputing them, so
    # prefill_tokens is the headline column (tok/s on CPU mostly tracks the
    # decode dispatches, which are identical).
    px_requests = max(16, requests)
    px_len = prompt_len * 8                    # e.g. 128-token system prompt
    tail_len = max(4, prompt_len // 2)
    common = sample_batch(jax.random.PRNGKey(777), spec, 1, px_len)[0]
    shared_reqs = [jnp.concatenate([
        common, sample_batch(jax.random.PRNGKey(800 + i), spec, 1,
                             tail_len)[0]]) for i in range(px_requests)]
    px_cache_len = int(shared_reqs[0].shape[0]) + gen + 8

    sx_eng = Engine(model, params, slots=batch, cache_len=px_cache_len,
                    k_steps=k_steps)
    sx_paged = Engine(model, params, slots=batch, cache_len=px_cache_len,
                      k_steps=k_steps, paged=True, block_size=block_size)
    sx_prefix = Engine(model, params, slots=batch, cache_len=px_cache_len,
                       k_steps=k_steps, paged=True, block_size=block_size,
                       prefix_cache=True, chunk_size=4 * block_size,
                       metrics=reg)
    sraced = _race({
        "engine": lambda: sx_eng.serve(shared_reqs, gen_tokens=gen,
                                       return_stats=True),
        "paged": lambda: sx_paged.serve(shared_reqs, gen_tokens=gen,
                                        return_stats=True),
        "prefix": lambda: sx_prefix.serve(shared_reqs, gen_tokens=gen,
                                          return_stats=True),
    })
    (sx_eng_outs, sx_eng_stats), sx_eng_dt = sraced["engine"]
    (sx_pag_outs, sx_pag_stats), sx_pag_dt = sraced["paged"]
    (sx_pfx_outs, sx_pfx_stats), sx_pfx_dt = sraced["prefix"]
    shared_parity = (sx_pag_outs == sx_eng_outs
                     and sx_pfx_outs == sx_eng_outs)
    if not shared_parity:
        print("bench_serve: WARNING: shared-prefix outputs differ (greedy "
              "parity violated)", flush=True)
    assert sx_pfx_stats["prefill_tokens"] < sx_pag_stats["prefill_tokens"], \
        "prefix cache must prefill strictly fewer tokens"

    # ---- long-prompt + decode mix (chunked prefill) ------------------------
    lp_lens = [px_len if i == 0 else prompt_len
               for i in range(max(8, requests))]
    lp_reqs = [sample_batch(jax.random.PRNGKey(900 + i), spec, 1, L)[0]
               for i, L in enumerate(lp_lens)]
    lp_cache_len = px_len + gen + 9            # fits the long prompt
    lp_eng = Engine(model, params, slots=batch, cache_len=lp_cache_len,
                    k_steps=k_steps)
    lp_paged = Engine(model, params, slots=batch, cache_len=lp_cache_len,
                      k_steps=k_steps, paged=True, block_size=block_size)
    lp_chunk = Engine(model, params, slots=batch, cache_len=lp_cache_len,
                      k_steps=k_steps, paged=True, block_size=block_size,
                      chunk_size=2 * block_size, metrics=reg)
    lraced = _race({
        "engine": lambda: lp_eng.serve(lp_reqs, gen_tokens=gen,
                                       return_stats=True),
        "paged": lambda: lp_paged.serve(lp_reqs, gen_tokens=gen,
                                        return_stats=True),
        "chunked": lambda: lp_chunk.serve(lp_reqs, gen_tokens=gen,
                                          return_stats=True),
    })
    (lp_eng_outs, lp_eng_stats), lp_eng_dt = lraced["engine"]
    (lp_pag_outs, lp_pag_stats), lp_pag_dt = lraced["paged"]
    (lp_chk_outs, lp_chk_stats), lp_chk_dt = lraced["chunked"]
    lp_parity = (lp_pag_outs == lp_eng_outs and lp_chk_outs == lp_eng_outs)
    if not lp_parity:
        print("bench_serve: WARNING: long-prompt outputs differ (greedy "
              "parity violated)", flush=True)

    result = {
        "workload": {"arch": arch, "requests": requests, "batch": batch,
                     "prompt_len": prompt_len, "gen": gen,
                     "k_steps": k_steps, "block_size": block_size,
                     "greedy_parity": parity},
        "old": _row(old_dt, old_stats),
        "engine": _row(eng_dt, eng_stats),
        "paged": _row(pag_dt, pag_stats),
        "mixed": {
            "prompt_lens": mixed_lens,
            "greedy_parity": mixed_parity,
            "num_blocks": num_blocks,
            "engine": _row(m_eng_dt, m_eng_stats),
            "paged": _row(m_pag_dt, m_pag_stats),
        },
        "shared_prefix": {
            "requests": px_requests,
            "prefix_len": px_len,
            "tail_len": tail_len,
            "greedy_parity": shared_parity,
            "engine": _row(sx_eng_dt, sx_eng_stats),
            "paged": _row(sx_pag_dt, sx_pag_stats),
            "prefix": {**_row(sx_pfx_dt, sx_pfx_stats),
                       "prefix_hits": sx_pfx_stats.get("prefix_hits", 0),
                       "prefix_evictions":
                           sx_pfx_stats.get("prefix_evictions", 0)},
        },
        "longprompt": {
            "prompt_lens": lp_lens,
            "greedy_parity": lp_parity,
            "engine": _row(lp_eng_dt, lp_eng_stats),
            "paged": _row(lp_pag_dt, lp_pag_stats),
            "chunked": _row(lp_chk_dt, lp_chk_stats),
        },
    }
    result["speedup"] = (result["engine"]["tok_per_s"]
                         / result["old"]["tok_per_s"])
    result["paged_vs_engine_uniform"] = (result["paged"]["tok_per_s"]
                                         / result["engine"]["tok_per_s"])
    result["mixed"]["cache_bytes_ratio"] = (
        result["mixed"]["paged"]["cache_bytes"]
        / max(result["mixed"]["engine"]["cache_bytes"], 1))
    result["meta"] = run_meta(result["workload"], metrics=reg)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    emit("serve.old_host_loop", old_dt * 1e6,
         f"tok_per_s={result['old']['tok_per_s']:.1f};"
         f"syncs_per_tok={result['old']['host_syncs_per_token']:.3f}")
    emit("serve.engine", eng_dt * 1e6,
         f"tok_per_s={result['engine']['tok_per_s']:.1f};"
         f"syncs_per_tok={result['engine']['host_syncs_per_token']:.3f}")
    emit("serve.paged", pag_dt * 1e6,
         f"tok_per_s={result['paged']['tok_per_s']:.1f};"
         f"cache_bytes={result['paged']['cache_bytes']}")
    emit("serve.speedup", 0, f"x={result['speedup']:.2f}")
    emit("serve.mixed.engine", m_eng_dt * 1e6,
         f"tok_per_s={result['mixed']['engine']['tok_per_s']:.1f};"
         f"cache_bytes={result['mixed']['engine']['cache_bytes']}")
    emit("serve.mixed.paged", m_pag_dt * 1e6,
         f"tok_per_s={result['mixed']['paged']['tok_per_s']:.1f};"
         f"cache_bytes={result['mixed']['paged']['cache_bytes']}")
    emit("serve.mixed.cache_ratio", 0,
         f"paged/contig={result['mixed']['cache_bytes_ratio']:.3f}")
    sx = result["shared_prefix"]
    sx["prefill_tokens_ratio"] = (sx["prefix"]["prefill_tokens"]
                                  / max(sx["paged"]["prefill_tokens"], 1))
    emit("serve.shared.paged", sx_pag_dt * 1e6,
         f"tok_per_s={sx['paged']['tok_per_s']:.1f};"
         f"prefill_tokens={sx['paged']['prefill_tokens']}")
    emit("serve.shared.prefix", sx_pfx_dt * 1e6,
         f"tok_per_s={sx['prefix']['tok_per_s']:.1f};"
         f"prefill_tokens={sx['prefix']['prefill_tokens']};"
         f"hits={sx['prefix']['prefix_hits']}")
    emit("serve.shared.prefill_ratio", 0,
         f"prefix/paged={sx['prefill_tokens_ratio']:.3f}")
    lp = result["longprompt"]
    emit("serve.longprompt.paged", lp_pag_dt * 1e6,
         f"tok_per_s={lp['paged']['tok_per_s']:.1f}")
    emit("serve.longprompt.chunked", lp_chk_dt * 1e6,
         f"tok_per_s={lp['chunked']['tok_per_s']:.1f};"
         f"prefill_tokens={lp['chunked']['prefill_tokens']}")
    return result


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--k-steps", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(args.arch, args.requests, args.batch, args.prompt_len, args.gen,
        args.k_steps, args.block_size, args.out)


if __name__ == "__main__":
    main()
