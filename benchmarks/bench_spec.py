"""Self-speculative serving bench: per-quantization-method draft acceptance
rate + tok/s vs the non-speculative paged engine, plus the composed
shared-system-prompt workload — speculation × prefix cache × chunked
prefill — reporting acceptance rate, prefix-hit rate and tok/s per method
(BENCH_spec.json).

This measures the paper's claim where it matters — in the serving hot path:
the quantized tree drafts, the full-precision tree verifies, and the
**draft acceptance rate** is a data-free token-level behavioral-fidelity
metric for the quantization method.  A delta-aware method (``daq``) should
draft closer to the full-precision model than the reconstruction-only
baseline (``absmax``) on the same weights — acceptance is the end-to-end
readout of that.  Greedy parity vs the non-speculative engine is asserted
in-bench (the lossless guarantee), so the tok/s column is a pure scheduling
comparison: identical tokens, fewer serial verifier steps.  On CPU the
verify forward costs ~C single steps, so tok/s gains need a memory-bound
accelerator; the acceptance columns are hardware-independent.

  PYTHONPATH=src python -m benchmarks.bench_spec [--gen 24 --n-spec 4 ...]
  PYTHONPATH=src python -m benchmarks.run spec       # same, CSV + JSON

Writes ``BENCH_spec.json`` and prints ``benchmarks.common.emit`` CSV rows.
Each engine is warmed once; the second run is timed (best of N).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_meta
from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine
from repro.models import build_model
from repro.quantize import quantize


def _race(fns: dict, repeats: int = 3) -> dict:
    outs = {name: fn() for name, fn in fns.items()}      # warm
    best = {name: float("inf") for name in fns}
    for _ in range(repeats):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            outs[name] = fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: (outs[name], best[name]) for name in fns}


def run(arch: str = "glm4-9b", requests: int = 8, batch: int = 4,
        prompt_len: int = 16, gen: int = 24, k_steps: int = 8,
        n_spec: int = 4, block_size: int = 8,
        methods: tuple = ("daq", "absmax"),
        out_path: str = "BENCH_spec.json") -> dict:
    from repro.telemetry import MetricsRegistry
    reg = MetricsRegistry()   # shared: all engines' lifecycle metrics
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = LanguageSpec(vocab=cfg.vocab_size)
    # a perturbed base stands in for a real base checkpoint: the delta
    # ΔW = W_post - W_base is then non-trivial, so delta-aware methods
    # have something to preserve (see launch/serve.py --base-ckpt for
    # serving against a real base tree)
    base = jax.tree.map(
        lambda p: p - 0.01 * jnp.ones_like(p) * (p.ndim >= 2), params)
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, prompt_len)[0]
               for i in range(requests)]
    cache_len = prompt_len + gen + n_spec + 8

    peng = Engine(model, params, slots=batch, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=block_size)
    engines = {"paged": lambda: peng.serve(prompts, gen_tokens=gen,
                                           return_stats=True)}
    drafts = {}
    drafts_trees = {}
    for method in methods:
        qcfg = QuantConfig(method=method, granularity="channel")
        dtree, rep = quantize(params, base, qcfg, mode="storage",
                              out_dtype="bfloat16")
        drafts[method] = rep
        drafts_trees[method] = dtree
        eng = Engine(model, params, slots=batch, cache_len=cache_len,
                     k_steps=k_steps, paged=True, block_size=block_size,
                     n_spec=n_spec, draft_params=dtree, metrics=reg)
        engines[f"spec-{method}"] = (
            lambda e=eng: e.serve(prompts, gen_tokens=gen,
                                  return_stats=True))

    raced = _race(engines)
    (base_outs, base_stats), base_dt = raced["paged"]
    result = {
        "workload": {"arch": arch, "requests": requests, "batch": batch,
                     "prompt_len": prompt_len, "gen": gen,
                     "k_steps": k_steps, "n_spec": n_spec,
                     "block_size": block_size,
                     "methods": list(methods)},
        "paged": {"tok_per_s": base_stats["tokens"] / base_dt,
                  "wall_s": base_dt, "tokens": base_stats["tokens"],
                  "host_syncs": base_stats["host_syncs"]},
        "methods": {},
    }
    for method in methods:
        (outs, stats), dt = raced[f"spec-{method}"]
        parity = outs == base_outs
        assert parity, (f"speculative greedy parity violated for draft "
                        f"method {method!r}")
        acc = (stats["draft_accepted"] / stats["draft_tokens"]
               if stats["draft_tokens"] else 0.0)
        row = {
            "tok_per_s": stats["tokens"] / dt,
            "wall_s": dt,
            "tokens": stats["tokens"],
            "host_syncs": stats["host_syncs"],
            "greedy_parity": parity,
            "acceptance_rate": acc,
            "draft_tokens": stats["draft_tokens"],
            "draft_accepted": stats["draft_accepted"],
            "spec_rounds": stats["spec_rounds"],
            "speedup_vs_paged": (stats["tokens"] / dt)
            / (base_stats["tokens"] / base_dt),
            "draft_sign_rate": drafts[method].global_chosen.get(
                "sign_rate", 0.0),
        }
        result["methods"][method] = row
        emit(f"spec.{method}", dt * 1e6,
             f"tok_per_s={row['tok_per_s']:.1f};"
             f"acceptance={acc:.3f};"
             f"speedup={row['speedup_vs_paged']:.2f}")
    emit("spec.paged_baseline", base_dt * 1e6,
         f"tok_per_s={result['paged']['tok_per_s']:.1f}")
    result["shared_prefix"] = _run_shared(
        model, params, drafts_trees, spec, batch=batch, requests=requests,
        gen=gen, k_steps=k_steps, n_spec=n_spec, block_size=block_size,
        metrics=reg)
    result["meta"] = run_meta(result["workload"], metrics=reg)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def _run_shared(model, params, drafts_trees: dict, spec, *, batch: int,
                requests: int, gen: int, k_steps: int, n_spec: int,
                block_size: int, system_len: int = 32,
                tail_len: int = 16, chunk: int = 16, metrics=None) -> dict:
    """The composed serving workload: every request opens with the same
    system prompt, engines run speculation × prefix cache × chunked
    prefill.  ``_race`` warms each engine once, so the timed passes hit a
    warm prefix index — the system prompt's blocks are shared, not
    recomputed — while the quantized tree drafts.  Reports the prefix-hit
    rate (prompt tokens served from cache) next to the acceptance rate:
    the two multiplicative sources of saved verifier forwards."""
    system = sample_batch(jax.random.PRNGKey(99), spec, 1, system_len)[0]
    prompts = [jnp.concatenate(
        [system,
         sample_batch(jax.random.PRNGKey(100 + i), spec, 1, tail_len)[0]])
        for i in range(requests)]
    L = system_len + tail_len
    cache_len = L + gen + n_spec + 8

    def mk(dtree=None):
        kw = dict(n_spec=n_spec, draft_params=dtree) if dtree is not None \
            else {}
        return Engine(model, params, slots=batch, cache_len=cache_len,
                      k_steps=k_steps, paged=True, block_size=block_size,
                      chunk_size=chunk, prefix_cache=True, metrics=metrics,
                      **kw)

    beng = mk()
    engines = {"prefix": lambda: beng.serve(prompts, gen_tokens=gen,
                                            return_stats=True)}
    for method, dtree in drafts_trees.items():
        eng = mk(dtree)
        engines[f"spec-{method}"] = (
            lambda e=eng: e.serve(prompts, gen_tokens=gen,
                                  return_stats=True))
    raced = _race(engines)
    (base_outs, base_stats), base_dt = raced["prefix"]

    def hit_rate(stats):
        seen = stats["prefix_hits"] + stats["prefill_tokens"]
        return stats["prefix_hits"] / seen if seen else 0.0

    out = {
        "workload": {"system_len": system_len, "tail_len": tail_len,
                     "chunk_size": chunk, "requests": requests,
                     "batch": batch, "gen": gen},
        "prefix_baseline": {"tok_per_s": base_stats["tokens"] / base_dt,
                            "wall_s": base_dt,
                            "prefix_hit_rate": hit_rate(base_stats)},
        "methods": {},
    }
    for method in drafts_trees:
        (outs, stats), dt = raced[f"spec-{method}"]
        assert outs == base_outs, (
            f"composed speculative greedy parity violated for {method!r}")
        acc = (stats["draft_accepted"] / stats["draft_tokens"]
               if stats["draft_tokens"] else 0.0)
        row = {
            "tok_per_s": stats["tokens"] / dt,
            "wall_s": dt,
            "greedy_parity": True,
            "acceptance_rate": acc,
            "prefix_hit_rate": hit_rate(stats),
            "final_spec_depth": stats["spec_depth"],
            "speedup_vs_prefix": (stats["tokens"] / dt)
            / (base_stats["tokens"] / base_dt),
        }
        out["methods"][method] = row
        emit(f"spec.shared.{method}", dt * 1e6,
             f"tok_per_s={row['tok_per_s']:.1f};"
             f"acceptance={acc:.3f};"
             f"prefix_hit={row['prefix_hit_rate']:.3f}")
    emit("spec.shared.prefix_baseline", base_dt * 1e6,
         f"tok_per_s={out['prefix_baseline']['tok_per_s']:.1f};"
         f"prefix_hit={out['prefix_baseline']['prefix_hit_rate']:.3f}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--k-steps", type=int, default=8)
    ap.add_argument("--n-spec", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--methods", nargs="+", default=["daq", "absmax"])
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args(argv)
    run(args.arch, args.requests, args.batch, args.prompt_len, args.gen,
        args.k_steps, args.n_spec, args.block_size, tuple(args.methods),
        args.out)


if __name__ == "__main__":
    main()
