"""Benchmark utilities: timed jit calls, CSV emission, provenance stamps."""
from __future__ import annotations

import hashlib
import json
import subprocess
import time
from pathlib import Path

import jax


def run_meta(workload: dict | None = None, metrics=None) -> dict:
    """Provenance stamp for benchmark artifacts: commit SHA (suffixed
    ``-dirty`` when the tree has uncommitted changes), jax version and
    backend, and a fingerprint of the workload config — so two BENCH
    files are comparable (or provably not) at a glance.

    ``metrics`` (a ``repro.telemetry.MetricsRegistry``) embeds the run's
    metrics snapshot under ``meta["metrics"]`` — the same stable-schema
    JSON the serve CLI writes, so benchmark artifacts diff against serve
    runs with the same tooling."""
    here = Path(__file__).resolve().parent
    sha = "unknown"
    try:
        r = subprocess.run(["git", "rev-parse", "--short=12", "HEAD"],
                           capture_output=True, text=True, cwd=here,
                           timeout=10)
        if r.returncode == 0 and r.stdout.strip():
            sha = r.stdout.strip()
            d = subprocess.run(["git", "status", "--porcelain"],
                               capture_output=True, text=True, cwd=here,
                               timeout=10)
            if d.returncode == 0 and d.stdout.strip():
                sha += "-dirty"
    except (OSError, subprocess.SubprocessError):
        pass
    meta = {
        "commit": sha,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    if workload is not None:
        blob = json.dumps(workload, sort_keys=True, default=str)
        meta["config_fingerprint"] = hashlib.sha256(
            blob.encode()).hexdigest()[:16]
    if metrics is not None:
        meta["metrics"] = metrics.snapshot()
    return meta


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> dict:
    """Print one CSV result line and return it as a row dict, so callers
    can collect rows for a ``write_bench`` artifact."""
    print(f"{name},{us:.1f},{derived}", flush=True)
    return {"name": name, "us": round(us, 1), "derived": derived}


def write_bench(path, rows: list[dict], workload: dict | None = None,
                metrics=None) -> None:
    """Write a ``BENCH_*.json`` artifact: ``run_meta`` provenance (commit,
    backend, config fingerprint, optional metrics snapshot) + the result
    rows — the machine-diffable counterpart of the CSV stdout."""
    doc = {"meta": run_meta(workload, metrics=metrics), "rows": rows}
    Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"[bench] wrote {path} ({len(rows)} rows)", flush=True)
