"""Benchmark utilities: timed jit calls, CSV emission, provenance stamps."""
from __future__ import annotations

import hashlib
import json
import subprocess
import time
from pathlib import Path

import jax


def run_meta(workload: dict | None = None) -> dict:
    """Provenance stamp for benchmark artifacts: commit SHA (suffixed
    ``-dirty`` when the tree has uncommitted changes), jax version and
    backend, and a fingerprint of the workload config — so two BENCH
    files are comparable (or provably not) at a glance."""
    here = Path(__file__).resolve().parent
    sha = "unknown"
    try:
        r = subprocess.run(["git", "rev-parse", "--short=12", "HEAD"],
                           capture_output=True, text=True, cwd=here,
                           timeout=10)
        if r.returncode == 0 and r.stdout.strip():
            sha = r.stdout.strip()
            d = subprocess.run(["git", "status", "--porcelain"],
                               capture_output=True, text=True, cwd=here,
                               timeout=10)
            if d.returncode == 0 and d.stdout.strip():
                sha += "-dirty"
    except (OSError, subprocess.SubprocessError):
        pass
    meta = {
        "commit": sha,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    if workload is not None:
        blob = json.dumps(workload, sort_keys=True, default=str)
        meta["config_fingerprint"] = hashlib.sha256(
            blob.encode()).hexdigest()[:16]
    return meta


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
