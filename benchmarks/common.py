"""Benchmark utilities: timed jit calls, CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
