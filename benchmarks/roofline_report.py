"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod16x16]
Prints a markdown table (also written to experiments/roofline_<mesh>.md).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ORDER = ["glm4-9b", "command-r-35b", "phi3-medium-14b", "deepseek-67b",
         "mamba2-780m", "jamba-v0.1-52b", "mixtral-8x22b", "kimi-k2-1t-a32b",
         "seamless-m4t-medium", "llama-3.2-vision-90b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def load(mesh: str, dirname: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(mesh: str, dirname: str = "experiments/dryrun") -> str:
    rows = load(mesh, dirname)
    by_key = {(r["arch"], r["shape"]): r for r in rows}
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful FLOPs | MFU bound | peak mem | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ORDER:
        for shape in SHAPES:
            r = by_key.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped "
                             f"(full attention @512k) | — | — | — | — |")
                continue
            if r["status"] == "error":
                lines.append(f"| {arch} | {shape} | ERROR: "
                             f"{r['error'][:40]} | | | | | | | |")
                continue
            rl = r["roofline"]
            mem = (r["memory"]["argument_bytes"] - r["memory"]["alias_bytes"]
                   + r["memory"]["temp_bytes"]) / 2 ** 30
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | "
                f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                f"{rl['dominant']} | {rl['useful_flops_frac']*100:.0f}% | "
                f"{rl['mfu_bound']*100:.1f}% | {mem:.1f}GiB | "
                f"{'yes' if mem < 16 else 'NO'} |")
    return "\n".join(lines)


def worst_cells(mesh: str, dirname: str = "experiments/dryrun", n: int = 5):
    rows = [r for r in load(mesh, dirname) if r.get("status") == "ok"]
    def frac(r):
        return r["roofline"]["mfu_bound"]
    rows.sort(key=frac)
    out = []
    for r in rows[:n]:
        out.append((r["arch"], r["shape"], r["roofline"]["dominant"],
                    r["roofline"]["mfu_bound"]))
    coll = sorted(rows, key=lambda r: -r["roofline"]["collective_s"])[:n]
    return out, [(r["arch"], r["shape"], r["roofline"]["collective_s"])
                 for r in coll]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    t = table(args.mesh, args.dir)
    print(t)
    out = f"experiments/roofline_{args.mesh}.md"
    with open(out, "w") as f:
        f.write(t + "\n")
    worst, coll = worst_cells(args.mesh, args.dir)
    print("\nworst MFU-bound cells:", worst)
    print("most collective-bound:", coll)


if __name__ == "__main__":
    main()
