"""Benchmark orchestrator — one entry per paper table plus kernel/system
micro-benches.  Output format: ``name,us_per_call,derived`` CSV rows (tables
additionally print their rows as they compute).

  PYTHONPATH=src python -m benchmarks.run                # everything
  PYTHONPATH=src python -m benchmarks.run table2 table4  # subset
  PYTHONPATH=src python -m benchmarks.run kernels

Paper-table benches reuse the cached study checkpoints under
``experiments/study`` (first invocation trains them: ~10 min CPU).
"""
from __future__ import annotations

import sys
import time


def _timed(name, fn):
    t0 = time.perf_counter()
    fn()
    print(f"{name},{(time.perf_counter()-t0)*1e6:.0f},total_wall", flush=True)


def table(n: str):
    from repro.pipeline.daq_study import run_tables
    _timed(f"table{n}", lambda: run_tables(tables=(n,)))


def kernels():
    from benchmarks import bench_kernels
    bench_kernels.main()


def train_throughput():
    """tokens/s of the reduced-config training step (system bench)."""
    import jax
    from benchmarks.common import emit, time_call
    from repro.configs import TrainConfig, get_arch, reduced
    from repro.data import LanguageSpec, train_batch
    from repro.launch.steps import init_train_state, make_train_step
    from repro.models import build_model

    cfg = reduced(get_arch("glm4-9b"))
    tc = TrainConfig()
    model = build_model(cfg)
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    spec = LanguageSpec(vocab=cfg.vocab_size)
    batch = train_batch(spec, 0, 0, 8, 128)
    step = jax.jit(make_train_step(model, tc))
    us = time_call(lambda: step(state, batch)[1]["loss"])
    emit("train.step_glm4smoke_b8s128", us,
         f"tok_per_s={8*128/(us/1e6):.0f}")


def decode_throughput():
    import jax
    import jax.numpy as jnp
    from benchmarks.common import emit, time_call
    from repro.configs import get_arch, reduced
    from repro.engine import SamplingParams, make_decode_dispatch
    from repro.engine.scheduler import init_slot_state
    from repro.models import build_model

    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(8, 256)
    cache["lengths"] = jnp.full((8,), 128, jnp.int32)
    state = init_slot_state(8)
    state["active"] = jnp.ones((8,), bool)
    state["remaining"] = jnp.full((8,), 10**6, jnp.int32)
    K = 8
    dispatch = jax.jit(make_decode_dispatch(model, SamplingParams(), K))
    key = jax.random.PRNGKey(0)
    us = time_call(lambda: dispatch(params, state, cache, key)[2])
    emit(f"serve.decode_glm4smoke_b8_cache256_k{K}", us,
         f"tok_per_s={8*K/(us/1e6):.0f}")


def serve_bench():
    """Legacy host loop vs device-resident engine (BENCH_serve.json)."""
    from benchmarks import bench_serve
    bench_serve.main([])


def spec_bench():
    """Speculative decoding: acceptance rate + tok/s per draft
    quantization method (BENCH_spec.json)."""
    from benchmarks import bench_spec
    bench_spec.main([])


def roofline():
    from benchmarks import roofline_report
    t = roofline_report.table("pod16x16")
    n = t.count("\n") - 1
    print(f"roofline.report,0,rows={n}", flush=True)


BENCHES = {
    "table2": lambda: table("2"),
    "table3": lambda: table("3"),
    "table4": lambda: table("4"),
    "table5": lambda: table("5"),
    "kernels": kernels,
    "train": train_throughput,
    "decode": decode_throughput,
    "serve": serve_bench,
    "spec": spec_bench,
    "roofline": roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
