"""Quickstart: Delta-Aware Quantization of a model in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a small LM, fabricates a (base, post-trained) pair, then quantizes
to FP8 through the one public entry point ``repro.quantize.quantize`` —
every method (the AbsMax baseline and each DAQ objective from the paper) is
just a different ``QuantConfig.method`` / ``metric``.  Watch
SignRate/CosSim improve under the delta-aware metrics at (slightly) higher
reconstruction MSE.
"""
import dataclasses

import jax

from repro.configs import QuantConfig, get_arch, reduced
from repro.models import build_model
from repro.quantize import quantize


def main():
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)

    # a "post-trained" model and its "base": the delta is small-magnitude,
    # exactly the regime DAQ targets (paper §1)
    params_post = model.init(jax.random.PRNGKey(0))
    params_base = jax.tree.map(
        lambda p: (p - 0.003 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape).astype(p.dtype))
        if p.ndim >= 2 else p, params_post)

    print(f"model: {cfg.name} "
          f"({sum(x.size for x in jax.tree.leaves(params_post)):,} params)")
    print(f"{'objective':>10s} {'alpha-range':>12s} {'SignRate':>9s} "
          f"{'CosSim':>8s} {'ΔW-L2':>9s} {'MSE':>10s}")

    q0 = QuantConfig(granularity="block", block_size=32,
                     alpha_min=0.8, alpha_max=1.25)

    def row(name, arange, rep):
        g = rep.global_chosen
        print(f"{name:>10s} {arange:>12s} {g['sign_rate']:9.4f} "
              f"{g['cosine']:8.4f} {g['delta_l2']:9.4f} {g['mse']:10.3e}")

    _, rep = quantize(params_post, params_base,
                      dataclasses.replace(q0, method="absmax"))
    row("absmax", "-", rep)

    for metric in ("mse", "sign", "cosine", "hybrid"):
        q = dataclasses.replace(q0, method="daq", metric=metric)
        _, rep = quantize(params_post, params_base, q)
        row(metric, "[0.8,1.25]", rep)

    print("\nNote: 'sign'/'cosine' preserve the post-training delta's "
          "direction better than 'mse', at equal storage cost — the "
          "paper's core claim.")


if __name__ == "__main__":
    main()
