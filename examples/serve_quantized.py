"""Serve a DAQ-quantized model through the device-resident engine.

  PYTHONPATH=src python examples/serve_quantized.py

Compares dense-bf16 serving vs fp8 DAQ-quantized serving on the same
requests: same model code, QuantizedTensor leaves (quant_runtime/qlinear);
on TPU the fused dequant-matmul kernel takes over via USE_KERNELS.  Both
runs go through ``repro.engine.Engine`` — slot scheduling lives on device
and the host syncs once per ``k_steps`` decode steps.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine
from repro.models import build_model
from repro.quantize import quantize


def main():
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = jax.tree.map(
        lambda p: (p - 0.002 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape).astype(p.dtype))
        if p.ndim >= 2 else p, params)

    qcfg = QuantConfig(method="daq", metric="sign", granularity="channel")
    qparams, report = quantize(params, base, qcfg, mode="storage",
                               out_dtype="bfloat16")
    print(report.summary())

    spec = LanguageSpec(vocab=cfg.vocab_size)
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, 16)[0]
               for i in range(6)]

    for name, p in (("bf16", params), ("fp8-DAQ", qparams)):
        eng = Engine(model, p, slots=2, cache_len=40, k_steps=8)
        t0 = time.time()
        outs, stats = eng.serve(prompts, gen_tokens=8, return_stats=True)
        dt = time.time() - t0
        n = sum(len(o) for o in outs)
        print(f"{name:8s}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s, "
              f"{stats['host_syncs']} host syncs); "
              f"first request -> {outs[0]}")


if __name__ == "__main__":
    main()
