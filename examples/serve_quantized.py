"""Serve a DAQ-quantized model with the slot-based continuous batcher.

  PYTHONPATH=src python examples/serve_quantized.py

Compares dense-bf16 serving vs fp8 DAQ-quantized serving on the same
requests: same model code, QuantizedTensor leaves (quant_runtime/qlinear);
on TPU the fused dequant-matmul kernel takes over via USE_KERNELS.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.launch.serve import serve
from repro.models import build_model
from repro.quantize import quantize


def main():
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = jax.tree.map(
        lambda p: (p - 0.002 * jax.random.normal(
            jax.random.PRNGKey(1), p.shape).astype(p.dtype))
        if p.ndim >= 2 else p, params)

    qcfg = QuantConfig(method="daq", metric="sign", granularity="channel")
    qparams, report = quantize(params, base, qcfg, mode="storage",
                               out_dtype="bfloat16")
    print(report.summary())

    spec = LanguageSpec(vocab=cfg.vocab_size)
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, 16)[0]
               for i in range(6)]

    for name, p in (("bf16", params), ("fp8-DAQ", qparams)):
        t0 = time.time()
        outs = serve(model, p, prompts, batch=2, gen_tokens=8, cache_len=40)
        dt = time.time() - t0
        n = sum(len(o) for o in outs)
        print(f"{name:8s}: {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s); "
              f"first request -> {outs[0]}")


if __name__ == "__main__":
    main()
