"""End-to-end driver (assignment deliverable b): train a base model for a
few hundred steps, SFT it on the stylized corpus, quantize with every DAQ
objective, and evaluate Style/General — the paper's full experimental loop
at CPU scale.

  PYTHONPATH=src python examples/sft_then_quantize.py [--fast]

(--fast uses a reduced training budget; full tables via
 ``python -m benchmarks.run table2 table3 table4 table5``.)
"""
import argparse

from repro.configs import QuantConfig
from repro.pipeline import daq_study as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--study-dir", default="/tmp/daq_example")
    args = ap.parse_args()

    kw = dict(base_steps=200, sft_steps=80) if args.fast else {}
    model, params_base, params_post = S.prepare_models(
        study_dir=args.study_dir, **kw)
    spec = S.language()

    print("\n-- BF16 endpoints --")
    for name, p in (("base", params_base), ("post-SFT", params_post)):
        s = S.evaluate(model, p, spec)
        print(f"{name:9s} style={s['style']:.3f} general={s['general']:.3f}")

    print("\n-- FP8 quantization (block 32) --")
    rows = {
        "absmax": QuantConfig(method="absmax", granularity="block",
                              block_size=32),
        "smoothquant": QuantConfig(method="smoothquant",
                                   granularity="channel"),
        "mse-search": QuantConfig(metric="mse", granularity="block",
                                  block_size=32, alpha_min=0.9,
                                  alpha_max=1.11),
        "DAQ-sign": QuantConfig(metric="sign", granularity="block",
                                block_size=32, alpha_min=0.8,
                                alpha_max=1.25),
        "DAQ-cosine": QuantConfig(metric="cosine", granularity="block",
                                  block_size=32, alpha_min=0.9,
                                  alpha_max=1.11),
    }
    for name, q in rows.items():
        r = S.quantize_and_eval(model, params_post, params_base, q, spec)
        print(f"{name:11s} style={r['style']:.3f} general={r['general']:.3f} "
              f"sign={r['sign_rate']:.3f} cos={r['cosine']:.3f} "
              f"ΔL2={r['delta_l2']:.2f}")


if __name__ == "__main__":
    main()
