from repro.analysis.hlo import collective_bytes, dominant_ops
from repro.analysis.roofline import (Roofline, model_flops_estimate,
                                     roofline_from_costs)

__all__ = ["collective_bytes", "dominant_ops", "Roofline",
           "model_flops_estimate", "roofline_from_costs"]
