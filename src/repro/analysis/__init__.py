from repro.analysis.hlo import (HloModule, collective_bytes, dominant_ops,
                                parse_input_output_aliases)
from repro.analysis.roofline import (Roofline, model_flops_estimate,
                                     roofline_from_costs)

__all__ = ["HloModule", "collective_bytes", "dominant_ops",
           "parse_input_output_aliases", "Roofline",
           "model_flops_estimate", "roofline_from_costs"]
