"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — while
bodies (every ``lax.scan``: our layer stacks, flash-attention tiles, xent
chunks) are not multiplied by their trip counts, undercounting FLOPs by the
layer count (~20-100x).  This module parses the *partitioned* HLO text
(local per-device shapes) and computes:

  * flops   — 2*M*N*K for every ``dot``, scaled by the product of enclosing
              while trip counts (``backend_config.known_trip_count``);
  * bytes   — an HBM-traffic proxy: operands+result of every top-level
              instruction in non-fusion computations (fusion internals never
              touch HBM), same trip scaling;
  * collectives — payload per collective kind with ring-cost factors and
              trip scaling.

Fusion bodies get flops-multiplier (dots can live inside fusions) but a
bytes-multiplier of 0.  Scalar ``to_apply`` computations (reduce adders
etc.) are excluded from both.  ``lax.cond`` branches are counted at most
once per call (upper bound; causal tile-skipping makes actuals lower).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "while", "conditional", "call", "after-all",
                  "add-dependency", "partition-id", "replica-id"}

# Ops that cross the host boundary inside compiled code.  ``custom-call``
# is host-crossing only for callback targets (python callbacks registered
# by jax.debug.*, io_callback, pure_callback); plain custom-calls (e.g.
# cuDNN/oneDNN library kernels) stay on device.
HOST_OPS = {"infeed", "outfeed", "send", "recv", "send-done", "recv-done"}
_HOST_CALL_TARGET = re.compile(r"callback|host", re.IGNORECASE)
_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}\s*:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}\s*"
    r"(?:,\s*([\w-]+)\s*)?\)")


def _shape_elems(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt in DTYPE_BYTES:
            total += _shape_elems(dt, dims) * DTYPE_BYTES[dt]
    return total


def _balanced_braces(text: str, start: int) -> str:
    """The ``{...}`` segment (braces included) opening at ``start``."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    return text[start:]


def parse_input_output_aliases(text: str) -> list[dict]:
    """Donation aliases from the compiled module header.

    Compiled (post-buffer-assignment) HLO text carries
    ``input_output_alias={ {out_idx}: (param, {param_idx}, kind), ... }``
    on the ``HloModule`` line — the pairs XLA actually aliased.  A donated
    operand that is *absent* here was copied, not reused: the donation
    silently failed and the buffer is paid for twice.  Returns dicts with
    ``output_index`` / ``param_number`` / ``param_index`` / ``kind``.
    """
    key = "input_output_alias="
    pos = text.find(key)
    if pos < 0:
        return []
    seg = _balanced_braces(text, pos + len(key))
    out = []
    for m in _ALIAS_ENTRY_RE.finditer(seg):
        out.append({
            "output_index": tuple(int(x) for x in m.group(1).split(",")
                                  if x.strip()),
            "param_number": int(m.group(2)),
            "param_index": tuple(int(x) for x in m.group(3).split(",")
                                 if x.strip()),
            "kind": m.group(4) or "may-alias",
        })
    return out


class Instr:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.rest = rest          # everything after the opening paren

    def operands(self) -> list[str]:
        # Scan to the matching close paren of the operand list, then pull
        # the %name references.  Operand entries may carry full type
        # annotations ("f32[128,256]{1,0} %Arg_0.1", jax >= 0.4.3x text
        # format) whose commas must not split tokens — hence the regex over
        # the balanced segment instead of a comma tokenizer.
        depth = 1
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return re.findall(r"%([\w\.\-]+)", self.rest[:end])

    def attr(self, pattern: str) -> str | None:
        m = re.search(pattern, self.rest)
        return m.group(1) if m else None


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.shapes: dict[str, str] = {}   # instr name -> type string
        self._parse(text)
        self.mult_flops, self.mult_bytes = self._multipliers()
        self.aliases = parse_input_output_aliases(text)

    # -- compile-contract views -------------------------------------------

    def aliased_param_numbers(self) -> set[int]:
        """Entry parameter numbers that alias an output (donation landed)."""
        return {a["param_number"] for a in self.aliases}

    def entry_params(self) -> dict[int, str]:
        """``parameter(N)`` instructions of the entry computation:
        param number -> type string (local, post-partition shapes)."""
        entry = self.entry or (next(iter(self.computations))
                               if self.computations else None)
        out: dict[int, str] = {}
        for ins in self.computations.get(entry, []):
            if ins.op != "parameter":
                continue
            head = ins.rest.split(")", 1)[0].strip()
            if head.isdigit():
                out[int(head)] = ins.type_str
        return out

    def param_bytes(self, param_number: int) -> int:
        return _type_bytes(self.entry_params().get(param_number, ""))

    def host_ops(self) -> list[tuple[str, str, str]]:
        """Host-boundary crossings anywhere in the module: ``(computation,
        op, custom_call_target-or-'')`` for infeed/outfeed/send/recv and
        python-callback custom-calls.  Any hit inside a decode dispatch
        means a per-step host sync the K-step scan was built to avoid."""
        hits = []
        for comp, instrs in self.computations.items():
            for ins in instrs:
                if ins.op in HOST_OPS:
                    hits.append((comp, ins.op, ""))
                elif ins.op == "custom-call":
                    tm = _TARGET_RE.search(ins.rest)
                    target = tm.group(1) if tm else ""
                    if _HOST_CALL_TARGET.search(target):
                        hits.append((comp, ins.op, target))
        return hits

    def _parse(self, text: str) -> None:
        cur: list[Instr] | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            h = _HDR_RE.match(line.strip())
            if h and line.strip().endswith("{"):
                name = h.group(2)
                cur = []
                self.computations[name] = cur
                if h.group(1):
                    self.entry = name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.append(ins)
            self.shapes[ins.name] = ins.type_str

    def _multipliers(self):
        """(flops multipliers, bytes multipliers) per computation."""
        # edges: comp -> [(callee, weight, kind)]
        edges: dict[str, list[tuple[str, float, str]]] = defaultdict(list)
        for comp, instrs in self.computations.items():
            for ins in instrs:
                if ins.op == "while":
                    body = ins.attr(r"body=%?([\w\.\-]+)")
                    cond = ins.attr(r"condition=%?([\w\.\-]+)")
                    t = _TRIP_RE.search(ins.rest)
                    trips = float(t.group(1)) if t else 1.0
                    if body:
                        edges[comp].append((body, trips, "while"))
                    if cond:
                        edges[comp].append((cond, 0.0, "cond_check"))
                elif ins.op == "fusion":
                    callee = ins.attr(r"calls=%?([\w\.\-]+)")
                    if callee:
                        edges[comp].append((callee, 1.0, "fusion"))
                elif ins.op == "conditional":
                    for b in re.findall(r"branch_computations=\{([^}]*)\}",
                                        ins.rest):
                        for c in b.split(","):
                            edges[comp].append((c.strip().lstrip("%"), 1.0,
                                                "branch"))
                    tb = ins.attr(r"true_computation=%?([\w\.\-]+)")
                    fb = ins.attr(r"false_computation=%?([\w\.\-]+)")
                    for c in (tb, fb):
                        if c:
                            edges[comp].append((c, 1.0, "branch"))
                elif ins.op in ("call", "async-start"):
                    callee = ins.attr(r"to_apply=%?([\w\.\-]+)")
                    if callee:
                        edges[comp].append((callee, 1.0, "call"))
                # reduce/map/scatter to_apply: scalar computations — excluded

        entry = self.entry or next(iter(self.computations))
        mf: dict[str, float] = defaultdict(float)
        mb: dict[str, float] = defaultdict(float)
        mf[entry] = mb[entry] = 1.0
        # call graph is a DAG: recompute from callers until fixpoint
        for _ in range(64):
            nf: dict[str, float] = defaultdict(float)
            nb: dict[str, float] = defaultdict(float)
            nf[entry] = nb[entry] = 1.0
            for comp, es in edges.items():
                for callee, w, kind in es:
                    nf[callee] += mf[comp] * w
                    nb[callee] += mb[comp] * (0.0 if kind == "fusion" else w)
            if (all(abs(nf[k] - mf[k]) < 1e-6 for k in set(nf) | set(mf))
                    and all(abs(nb[k] - mb[k]) < 1e-6
                            for k in set(nb) | set(mb))):
                break
            mf, mb = nf, nb
        return mf, mb

    # -- costs ------------------------------------------------------------

    def _fusion_is_inplace(self, ins: "Instr") -> bool:
        """True when the fusion's body ends in a dynamic-update-slice of the
        fusion's own result type (an aliased in-place buffer update)."""
        callee = ins.attr(r"calls=%?([\w\.\-]+)")
        res = ins.type_str.split("{")[0]
        for body_ins in self.computations.get(callee or "", []):
            if body_ins.op == "dynamic-update-slice" \
                    and body_ins.type_str.split("{")[0] == res:
                return True
        return False

    def flops(self) -> float:
        total = 0.0
        for comp, instrs in self.computations.items():
            mult = self.mult_flops.get(comp, 0.0)
            if mult == 0.0:
                continue
            for ins in instrs:
                if ins.op not in ("dot", "convolution"):
                    continue
                out_elems = 0
                for m in _SHAPE_RE.finditer(ins.type_str):
                    if m.group(1) in DTYPE_BYTES:
                        out_elems += _shape_elems(m.group(1), m.group(2))
                k = 1
                ops = ins.operands()
                if ins.op == "dot" and ops:
                    lhs_shape = self.shapes.get(ops[0], "")
                    sm = _SHAPE_RE.search(lhs_shape)
                    cdims = ins.attr(r"lhs_contracting_dims=\{([0-9,]*)\}")
                    if sm and cdims:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for ci in cdims.split(","):
                            if ci:
                                k *= dims[int(ci)]
                total += mult * 2.0 * out_elems * k
        return total

    def bytes_accessed(self) -> float:
        total = 0.0
        for comp, instrs in self.computations.items():
            mult = self.mult_bytes.get(comp, 0.0)
            if mult == 0.0:
                continue
            for ins in instrs:
                if ins.op in SKIP_BYTES_OPS:
                    continue
                if ins.op == "dynamic-slice":
                    # reads only the slice, not the full operand
                    b = 2 * _type_bytes(ins.type_str)
                elif ins.op == "dynamic-update-slice":
                    # in-place (buffer-aliased) slice write: traffic is the
                    # update operand, not the whole buffer — without this,
                    # scan-carried KV caches count as full rewrites per
                    # token (~40x overcount observed on decode cells)
                    ops = ins.operands()
                    upd = self.shapes.get(ops[1], "") if len(ops) > 1 else ""
                    b = 2 * _type_bytes(upd)
                else:
                    op_types = [self.shapes.get(o, "")
                                for o in ins.operands()]
                    res = ins.type_str
                    b = _type_bytes(res) + sum(_type_bytes(t)
                                               for t in op_types)
                    if ins.op == "fusion" and self._fusion_is_inplace(ins):
                        # in-place update fusion (DUS on the result buffer):
                        # the buffer operand aliases the result — count the
                        # update delta only
                        for t in op_types:
                            if t and t.split("{")[0] == res.split("{")[0]:
                                b -= 2 * _type_bytes(t)
                                break
                total += mult * b
        return total

    def collectives(self, n_devices: int) -> dict:
        out: dict[str, float] = defaultdict(float)
        counts: dict[str, float] = defaultdict(float)
        for comp, instrs in self.computations.items():
            mult = self.mult_bytes.get(comp, 0.0)  # collectives never fused
            if mult == 0.0:
                continue
            for ins in instrs:
                kind = ins.op.replace("-start", "")
                if kind not in COLLECTIVES:
                    continue
                size = _type_bytes(ins.type_str)
                gm = _GROUPS_RE.search(ins.rest)
                n = n_devices
                if gm:
                    n = len([x for x in gm.group(1).split(",") if x.strip()])
                frac = (n - 1) / max(n, 1)
                factor = {"all-gather": frac, "reduce-scatter": frac,
                          "all-reduce": 2 * frac, "all-to-all": frac,
                          "ragged-all-to-all": frac,
                          "collective-permute": 1.0}[kind]
                out[kind] += size * factor * mult
                counts[kind] += mult
        out["total"] = sum(out.values())
        return {"bytes": dict(out), "counts": dict(counts)}


def analyze(hlo_text: str, n_devices: int) -> dict:
    mod = HloModule(hlo_text)
    return {"flops": mod.flops(), "bytes": mod.bytes_accessed(),
            "collectives": mod.collectives(n_devices)}


def collective_bytes(hlo_text: str, n_devices: int) -> dict:
    return HloModule(hlo_text).collectives(n_devices)


def dominant_ops(hlo_text: str, top: int = 8) -> list[tuple[str, float]]:
    """Largest local tensors in the module (GiB) — memory hot-spot hints."""
    sizes: dict[str, float] = {}
    for m in _SHAPE_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES or not dims:
            continue
        key = f"{dt}[{dims}]"
        sizes[key] = _shape_elems(dt, dims) * DTYPE_BYTES[dt]
    ranked = sorted(sizes.items(), key=lambda kv: -kv[1])[:top]
    return [(k, v / 2 ** 30) for k, v in ranked]
