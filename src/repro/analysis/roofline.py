"""Roofline terms from a compiled dry-run artifact (assignment §Roofline).

TPU v5e per-chip constants (the TARGET hardware; this container is CPU-only
so terms are derived from the compiled HLO, not measured):

  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI per link      : ~50 GB/s

Terms (seconds, per step, per chip — cost_analysis of an SPMD module is
already per-device):

  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / HBM_bw
  collective = per-device collective bytes / link_bw

MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE) with N taken from
the *actual parameter tree* (embedding excluded, the standard convention);
for decode cells D = global_batch tokens per step.  The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) shows how much compiled compute is useful
(catches remat recompute, masked-tile waste, padding).
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap estimate: max of the three terms (perfect overlap) —
        we report the max as the bound, the sum as the worst case."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        total = self.hlo_flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-bound step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.n_chips * PEAK_FLOPS)

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu,
        }


def model_flops_estimate(cfg, params_tree, shape, *, mode: str) -> float:
    """6*N*D with N = active non-embedding params, D = tokens this step."""
    import jax
    from repro.core.policy import path_str

    n_total = 0
    n_expert = 0
    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    for path, leaf in flat:
        name = path_str(path)
        size = 1
        for s in leaf.shape:
            size *= s
        if "embed" in name or "w_head" in name:
            continue
        n_total += size
        if "/moe/" in name and "shared" not in name and "router" not in name:
            n_expert += size
    if cfg.n_experts and cfg.top_k:
        active = n_total - n_expert + n_expert * cfg.top_k / cfg.n_experts
    else:
        active = n_total
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def roofline_from_costs(flops_per_chip: float, bytes_per_chip: float,
                        coll_bytes_per_chip: float, model_flops: float,
                        n_chips: int) -> Roofline:
    return Roofline(
        compute_s=flops_per_chip / PEAK_FLOPS,
        memory_s=bytes_per_chip / HBM_BW,
        collective_s=coll_bytes_per_chip / ICI_BW,
        model_flops=model_flops,
        hlo_flops_per_chip=flops_per_chip,
        hlo_bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
        n_chips=n_chips,
    )
