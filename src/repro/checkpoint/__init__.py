from repro.checkpoint.store import all_steps, latest, meta, restore, save

__all__ = ["all_steps", "latest", "meta", "restore", "save"]
