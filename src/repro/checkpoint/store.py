"""Fault-tolerant checkpointing.

Design goals (1000+ node deployment):

* **Atomicity** — a checkpoint is written to ``step_<N>.tmp-<nonce>/`` and
  ``rename``d into place only after every array file and the manifest have
  been fsync'd; a crash mid-write can never produce a readable-but-corrupt
  checkpoint, and ``latest()`` only ever sees complete ones.
* **Elasticity** — arrays are saved *unsharded by logical leaf* (each leaf is
  a separate ``.npy``), with the mesh shape recorded as metadata only.
  Restore places each leaf onto the *current* mesh with the *current*
  sharding rules, so a job restarted on a different host/chip count reads
  the same checkpoint (resharding is a ``device_put``).  On a real multi-pod
  deployment each leaf would be written as one file per shard by the hosts
  that own it (process-local IO) — the manifest layout already carries the
  per-leaf sharding to support that; this container has one process, so the
  gather-to-host path is exercised.
* **Retention** — ``keep_last`` checkpoints are retained; older ones are
  deleted only after the new one is durable.
* **Integrity** — every array file's byte size is recorded in the manifest
  and verified on load (cheap corruption check).

Pytree layout: leaves are addressed by their joined key-path, so any nested
dict-of-arrays (params, optimizer state, data-stream step counters) round
trips without schema registration.
"""
from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import path_str

MANIFEST = "manifest.json"


def _leaf_files(tree: Any) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = path_str(path).replace("/", ".")
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, keep_last: int = 3,
         extra_meta: dict | None = None) -> str:
    """Atomically save ``tree`` as ``<ckpt_dir>/step_<step>``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    manifest: dict = {"step": step, "leaves": {},
                      "meta": extra_meta or {}}
    for name, leaf in _leaf_files(tree):
        arr = np.asarray(jax.device_get(leaf))
        # ml_dtypes (bf16/fp8) round-trip natively through npy
        fn = os.path.join(tmp, name + ".npy")
        with open(fn, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][name] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "bytes": os.path.getsize(fn),
        }
    mf = os.path.join(tmp, MANIFEST)
    with open(mf, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):  # re-save of same step (restart past a crash)
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    # sweep orphaned tmp dirs from crashed writers
    for d in os.listdir(ckpt_dir):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and ".tmp-" not in d \
                and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)):
            out.append(int(d[len("step_"):]))
    return sorted(out)


def latest(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure or a prefix) places
    leaves onto the current mesh — the elastic-restart path."""
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(base, MANIFEST)) as f:
        manifest = json.load(f)
    names = [n for n, _ in _leaf_files(like)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint {base} missing leaves: {missing[:5]}...")

    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_sh = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
               if shardings is not None else [None] * len(flat_like))
    if len(flat_sh) == 1 and len(flat_like) > 1:
        flat_sh = flat_sh * len(flat_like)

    out = []
    for name, proto, sh in zip(names, flat_like, flat_sh):
        info = manifest["leaves"][name]
        fn = os.path.join(base, name + ".npy")
        if os.path.getsize(fn) != info["bytes"]:
            raise IOError(f"corrupt checkpoint leaf {name} "
                          f"({os.path.getsize(fn)} != {info['bytes']} bytes)")
        arr = np.load(fn)
        if arr.dtype.kind == "V":
            # np.load returns extended dtypes (bf16/fp8) as raw void —
            # reinterpret via the dtype recorded in the manifest
            arr = arr.view(np.dtype(info["dtype"]))
        if list(arr.shape) != list(proto.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {proto.shape}")
        if arr.dtype != proto.dtype:
            if arr.dtype.kind not in "iub":  # extended-float cross-casts
                arr = arr.astype(np.float32)  # bounce through f32
            arr = arr.astype(proto.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def meta(ckpt_dir: str, step: int) -> dict:
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(base, MANIFEST)) as f:
        return json.load(f)
