from repro.configs.base import (
    ModelConfig, ShapeConfig, QuantConfig, TrainConfig, RunConfig,
    LM_SHAPES, SHAPES_BY_NAME, shape_applicable,
)
from repro.configs.registry import ARCHS, ASSIGNED, get_arch, get_shape, all_cells, reduced

__all__ = [
    "ModelConfig", "ShapeConfig", "QuantConfig", "TrainConfig", "RunConfig",
    "LM_SHAPES", "SHAPES_BY_NAME", "shape_applicable",
    "ARCHS", "ASSIGNED", "get_arch", "get_shape", "all_cells", "reduced",
]
