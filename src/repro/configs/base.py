"""Configuration system for the DAQ reproduction framework.

Every model architecture is described by a single frozen dataclass
(`ModelConfig`).  Input shapes are described by `ShapeConfig`.  Quantization
settings by `QuantConfig`, training by `TrainConfig`, and meshes/launch by
`RunConfig`.  All configs are plain dataclasses so they can be constructed
from CLI flags, python, or JSON without any framework dependency.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` selects the assembly code path:
      dense   -- decoder-only transformer (GQA + RoPE + SwiGLU)
      moe     -- decoder-only transformer with mixture-of-experts FFN
      ssm     -- attention-free Mamba-2 (SSD) stack
      hybrid  -- Jamba-style interleave of Mamba + attention + MoE
      encdec  -- encoder-decoder transformer (speech/text, frontend stubbed)
      vlm     -- decoder-only transformer with interleaved cross-attention
                 layers attending to precomputed image patch embeddings
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0        # DeepSeek-V3 style shared expert(s)
    first_k_dense: int = 0           # first k layers use dense FFN
    d_ff_dense: int = 0              # dense FFN width when first_k_dense > 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0               # N: state dimension
    d_inner: int = 0                 # expanded inner width (0 -> 2*d_model)
    ssm_head_dim: int = 64           # P: SSD head dim
    ssm_chunk: int = 256             # SSD chunk length
    conv_kernel: int = 4

    # --- hybrid (Jamba) ---
    attn_every: int = 0              # one attention layer per this many layers
    moe_every: int = 0               # MoE FFN on layers where (idx % moe_every)==moe_offset
    moe_offset: int = 1

    # --- attention ---
    rope_theta: float = 10000.0
    sliding_window: int = 0          # >0 -> sliding-window attention (Mixtral)
    attn_logit_softcap: float = 0.0
    qkv_bias: bool = False

    # --- encoder-decoder ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_frames_cap: int = 4096       # max encoder memory length used in decode shapes

    # --- VLM ---
    cross_attn_every: int = 0        # one cross-attn layer per this many layers
    n_image_tokens: int = 1601       # patch embeddings per image (stub frontend)

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # --- provenance ---
    source: str = ""                 # citation from the assignment table
    subquadratic: bool = False       # can run long_500k decode
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.resolved_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic total parameter count (embedding included)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, Kv, hd = self.n_heads, self.n_kv_heads, self.resolved_head_dim
        embed = V * D
        head = 0 if self.tie_embeddings else D * V
        attn = D * H * hd + 2 * D * Kv * hd + H * hd * D

        def dense_ffn(width: int) -> int:
            return 3 * D * width  # SwiGLU: gate + up + down

        total = embed + head
        if self.family in ("dense", "vlm"):
            per_layer = attn + dense_ffn(F) + 2 * D
            total += self.n_layers * per_layer
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                total += n_cross * (attn + 2 * D)  # cross-attn projections+norms
        elif self.family == "moe":
            moe_ffn = self.n_experts * 3 * D * F + D * self.n_experts
            shared = self.n_shared_experts * 3 * D * F
            n_moe = self.n_layers - self.first_k_dense
            total += n_moe * (attn + moe_ffn + shared + 2 * D)
            total += self.first_k_dense * (attn + dense_ffn(self.d_ff_dense or F) + 2 * D)
        elif self.family == "ssm":
            di, N = self.resolved_d_inner, self.ssm_state
            nh = self.n_ssm_heads
            # in_proj -> [z, x, B, C, dt], conv over (x,B,C), out_proj
            per_layer = (D * (2 * di + 2 * N + nh) + self.conv_kernel * (di + 2 * N)
                         + 2 * nh + di + di * D + 2 * D)
            total += self.n_layers * per_layer
        elif self.family == "hybrid":
            di, N = self.resolved_d_inner, self.ssm_state
            nh = self.n_ssm_heads
            mamba_l = (D * (2 * di + 2 * N + nh) + self.conv_kernel * (di + 2 * N)
                       + 2 * nh + di + di * D + 2 * D)
            moe_ffn = self.n_experts * 3 * D * F + D * self.n_experts
            for idx in range(self.n_layers):
                is_attn = self.attn_every and (idx % self.attn_every == self.attn_every // 2)
                total += attn + 2 * D if is_attn else mamba_l
                is_moe = self.moe_every and (idx % self.moe_every == self.moe_offset)
                total += moe_ffn if is_moe else dense_ffn(F)
                total += D  # ffn norm
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + dense_ffn(F) + 2 * D)
            dec = self.n_dec_layers * (2 * attn + dense_ffn(F) + 3 * D)
            total += enc + dec
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k counting)."""
        if self.family not in ("moe", "hybrid") or not self.n_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        per_expert = 3 * D * F
        inactive = (self.n_experts - self.top_k) * per_expert
        if self.family == "moe":
            n_moe = self.n_layers - self.first_k_dense
        else:
            n_moe = sum(1 for idx in range(self.n_layers)
                        if self.moe_every and idx % self.moe_every == self.moe_offset)
        return self.param_count() - n_moe * inactive


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                         # train | prefill | decode


LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch x shape) cell should be run, and why not if skipped."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, ("pure full-attention architecture: 512k decode KV-cache "
                       "attention is quadratic-cost at prefill and the cache itself "
                       "is O(L*S); skipped per assignment, see DESIGN.md")
    return True, ""


# ---------------------------------------------------------------------------
# Quantization configuration (the paper's technique)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuantConfig:
    """Quantization settings (paper Sec. 2.2-2.4 plus baselines).

    ``method`` selects the algorithm from the ``repro.quantize`` registry:
    "daq" (paper Alg. 1, objective = ``metric``), "daq-per-block",
    "absmax", "smoothquant", "awq".
    """

    method: str = "daq"              # registry key (repro.quantize)
    fmt: str = "fp8_e4m3"            # fp8_e4m3 | fp8_e5m2 | int8 | int4
    granularity: str = "block"       # tensor | channel | block
    block_size: int = 128
    metric: str = "sign"             # sign | cosine | mse | hybrid
    alpha_min: float = 0.8
    alpha_max: float = 1.25
    n_coarse: int = 5
    n_fine: int = 10
    fine_delta: float = 0.0          # 0 -> one coarse grid step
    per_block_alpha: bool = False    # beyond-paper: independent alpha per block/channel
    use_fused_kernel: bool = False   # Pallas one-pass candidate sweep (block fp8)
    hybrid_lambda: float = 0.5       # hybrid = lambda*sign + (1-lambda)*cosine
    skip_patterns: tuple[str, ...] = ("norm", "bias", "router", "a_log", "ssm_dt", "conv")

    def resolved_fine_delta(self) -> float:
        if self.fine_delta:
            return self.fine_delta
        return (self.alpha_max - self.alpha_min) / max(self.n_coarse - 1, 1)


# ---------------------------------------------------------------------------
# Training / run configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0               # 0 -> no gradient accumulation
    remat: str = "full"               # none | full | dots_saveable
    opt_state_dtype: str = "float32"  # float32 | bfloat16 | int8 (8-bit Adam)
    grad_compress: str = "none"       # none | int8_ef (error-feedback int8 all-reduce)
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    arch: str = "glm4-9b"
    shape: str = "train_4k"
    multi_pod: bool = False
    fsdp: bool = True                 # shard params over the data axis too (ZeRO-3)
    use_quantized_weights: bool = False  # serve path with fp8 weights
    checkpoint_dir: str = "/tmp/repro_ckpt"
    save_every: int = 100
    keep_last: int = 3


def asdict(cfg: Any) -> dict:
    return dataclasses.asdict(cfg)


def replace(cfg: Any, **kw) -> Any:
    return dataclasses.replace(cfg, **kw)
