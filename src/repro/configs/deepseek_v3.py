"""DeepSeek-V3 671B: the paper's own experimental model (DAQ pilot study).

MLA is approximated with GQA kv=8 for this reproduction (noted in DESIGN.md
SS Hardware-adaptation): the DAQ technique operates on weight matrices and is
agnostic to the attention variant; keeping the MoE structure (256 routed
experts top-8 + 1 shared, first 3 layers dense) preserves the quantization
surface that matters for the delta-preservation study.

[arXiv:2412.19437; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,             # per-expert FFN width
    vocab_size=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    first_k_dense=3,
    d_ff_dense=18432,
    rope_theta=10000.0,
    source="arXiv:2412.19437; hf",
    subquadratic=False,
    notes="Paper's pilot model. MLA approximated as GQA (see DESIGN.md).",
)
