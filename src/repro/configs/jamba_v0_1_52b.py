"""Jamba v0.1 52B: hybrid Mamba + attention (1:7 interleave) with MoE 16e top-2.

[arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    ssm_state=16,
    d_inner=8192,          # 2 * d_model
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    attn_every=8,          # 1 attention layer per 8 (1:7 attn:mamba)
    moe_every=2,           # MoE FFN every other layer
    moe_offset=1,
    rope_theta=10000.0,
    source="arXiv:2403.19887; hf",
    subquadratic=True,
    notes="Mamba+attn 1:7 interleave, MoE every 2nd layer; only 4 attention "
          "layers -> small KV cache makes 512k decode feasible.",
)
