"""Kimi K2: trillion-parameter MoE (384 experts, top-8), DeepSeek-V3-style arch.

[arXiv:2501.kimi2; unverified] (paper-table config)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,             # per-expert FFN width
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    rope_theta=50000.0,
    source="arXiv:2501.kimi2; unverified",
    subquadratic=False,
    notes="Trillion-param MoE; head_dim=7168/64=112 (not 128-aligned -> MXU "
          "padding noted in roofline).",
)
