"""Llama-3.2-Vision 90B: dense LM backbone with interleaved cross-attention
layers attending to image patch embeddings.

The vision tower is a STUB: ``input_specs()`` provides precomputed patch
embeddings [batch, n_image_tokens, d_model] (assignment spec).

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,    # 20 cross-attention layers of 100
    n_image_tokens=1601,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    subquadratic=False,
    notes="cross-attn image layers every 5th; vision frontend stubbed.",
)
