"""Mamba-2 780M: attention-free SSM stack using SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,             # attention-free
    n_kv_heads=0,
    d_ff=0,                # no MLP; the Mamba block is the mixer+channel layer
    vocab_size=50280,
    ssm_state=128,
    d_inner=3072,          # 2 * d_model
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    source="arXiv:2405.21060; unverified",
    subquadratic=True,
    notes="SSD: chunked matmul-form scan; constant-size recurrent state at decode.",
)
