"""Mixtral 8x22B: MoE decoder-only, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1000000.0,
    source="arXiv:2401.04088; hf",
    subquadratic=True,     # SWA bounds the decode KV cache to the window
    notes="8 experts top-2, SWA window 4096 -> decode KV cache is O(window).",
)
