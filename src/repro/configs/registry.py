"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig, LM_SHAPES, SHAPES_BY_NAME

from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.deepseek_67b import CONFIG as _ds67
from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.mixtral_8x22b import CONFIG as _mixtral
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.llama_3_2_vision_90b import CONFIG as _llamav
from repro.configs.deepseek_v3 import CONFIG as _dsv3

# The 10 assigned architectures (order matters: it is the report order).
ASSIGNED: tuple[ModelConfig, ...] = (
    _glm4, _commandr, _phi3, _ds67, _mamba2,
    _jamba, _mixtral, _kimi, _seamless, _llamav,
)

# Paper's own model, available but not part of the 40-cell table.
EXTRA: tuple[ModelConfig, ...] = (_dsv3,)

ARCHS: dict[str, ModelConfig] = {c.name: c for c in ASSIGNED + EXTRA}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig]]:
    """All 40 (assigned arch x shape) cells, including inapplicable ones."""
    return [(a, s) for a in ASSIGNED for s in LM_SHAPES]


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/code path, tiny dims.
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, vocab: int = 512) -> ModelConfig:
    """A tiny config of the same family exercising every structural feature."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        vocab_size=vocab,
        d_ff=128 if cfg.d_ff else 0,
        head_dim=16,
        notes="reduced smoke config",
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(1, min(2, cfg.n_kv_heads))
    if cfg.family in ("moe", "hybrid"):
        kw["n_experts"] = min(8, cfg.n_experts)
        kw["top_k"] = min(2, cfg.top_k)
        if cfg.n_shared_experts:
            kw["n_shared_experts"] = 1
        if cfg.first_k_dense:
            kw["first_k_dense"] = 1
            kw["d_ff_dense"] = 192
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm_state"] = 16
        kw["d_inner"] = 128
        kw["ssm_head_dim"] = 16
        kw["ssm_chunk"] = 32
    if cfg.family == "hybrid":
        kw["n_layers"] = cfg.attn_every  # one full interleave group
    if cfg.family == "encdec":
        kw["n_enc_layers"] = 2
        kw["n_dec_layers"] = 2
        kw["n_layers"] = 4
        kw["enc_frames_cap"] = 64
    if cfg.family == "vlm":
        kw["n_layers"] = max(4, cfg.cross_attn_every)
        kw["cross_attn_every"] = min(2, cfg.cross_attn_every)
        kw["n_image_tokens"] = 17
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    return dataclasses.replace(cfg, **kw)
