"""SeamlessM4T-medium: encoder-decoder multimodal transformer backbone.

The speech/audio frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings of shape [batch, frames, d_model] (assignment spec).

[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,           # 12 encoder + 12 decoder
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,         # MHA (kv == heads)
    d_ff=4096,
    vocab_size=256206,
    enc_frames_cap=4096,
    act="gelu",
    norm="layernorm",
    source="arXiv:2308.11596; hf",
    subquadratic=False,
    notes="enc-dec; decode shapes = decoder self-cache of seq_len + cross-attn "
          "to capped encoder memory. Frontend stubbed as frame embeddings.",
)
