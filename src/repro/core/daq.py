"""Model-level DAQ: quantize a parameter pytree delta-aware.

``quantize_tree`` walks (params_post, params_base) in lockstep, runs the
coarse-to-fine scale search (Algorithm 1) on every quantizable leaf — with
stacked-layer leaves ``[L, I, O]`` handled by vmapping the per-matrix search
over the leading axes, i.e. one alpha per layer, exactly Alg. 1's per-layer
loop — and returns either

  * a tree of ``QuantizedTensor`` storage nodes (for serving), or
  * a tree of dequantized fp32/bf16 weights (for evaluation),

plus a :class:`QuantReport` with per-leaf and exact global delta metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core import metrics as M
from repro.core.policy import path_str, should_quantize
from repro.core.search import SearchResult, search_scale
from repro.quant_runtime.qparams import QuantizedTensor


@dataclass
class QuantReport:
    per_leaf: dict[str, dict] = field(default_factory=dict)
    global_chosen: dict[str, float] = field(default_factory=dict)
    global_default: dict[str, float] = field(default_factory=dict)
    n_quantized: int = 0
    n_skipped: int = 0
    quantized_bytes: int = 0
    original_bytes: int = 0

    def summary(self) -> str:
        g, d = self.global_chosen, self.global_default
        lines = [
            f"quantized {self.n_quantized} tensors ({self.n_skipped} skipped), "
            f"{self.original_bytes / 1e6:.1f} MB -> {self.quantized_bytes / 1e6:.1f} MB",
            f"  delta_l2   : {d.get('delta_l2', 0):.4g} -> {g.get('delta_l2', 0):.4g}",
            f"  sign_rate  : {d.get('sign_rate', 0):.4f} -> {g.get('sign_rate', 0):.4f}",
            f"  cosine     : {d.get('cosine', 0):.4f} -> {g.get('cosine', 0):.4f}",
            f"  mse        : {d.get('mse', 0):.4g} -> {g.get('mse', 0):.4g}",
        ]
        return "\n".join(lines)


def _leaf_search(w_post, w_base, qcfg: QuantConfig) -> SearchResult:
    """Search on a >=2-D leaf; leading axes (stacked layers) are vmapped."""
    fn = lambda p, b: search_scale(p, b, qcfg)
    for _ in range(w_post.ndim - 2):
        fn = jax.vmap(fn)
    return fn(w_post, w_base)


def _scalar_sum(x) -> float:
    return float(jnp.sum(x))


def quantize_tree(params_post: Any, params_base: Any, qcfg: QuantConfig,
                  *, mode: str = "dequant",
                  out_dtype: str = "float32") -> tuple[Any, QuantReport]:
    """Quantize every eligible leaf of ``params_post`` delta-aware.

    mode:
      "dequant" -- return dequantized float weights (evaluation / benchmarks)
      "storage" -- return QuantizedTensor nodes (serving)
    """
    report = QuantReport()
    post_leaves, treedef = jax.tree_util.tree_flatten_with_path(params_post)
    base_leaves = jax.tree_util.tree_leaves(params_base)
    if len(post_leaves) != len(base_leaves):
        raise ValueError("post/base parameter trees differ in structure")

    partial_keys = ("sq_err", "n_sign_match", "dot", "dp_sq", "dq_sq", "count")
    agg_c = {k: 0.0 for k in partial_keys}
    agg_d = {k: 0.0 for k in partial_keys}

    out_leaves = []
    for (path, w_post), w_base in zip(post_leaves, base_leaves):
        name = path_str(path)
        if not should_quantize(name, w_post, qcfg.skip_patterns):
            report.n_skipped += 1
            out_leaves.append(w_post)
            continue
        res = _leaf_search(w_post, w_base, qcfg)
        report.n_quantized += 1
        report.original_bytes += w_post.size * w_post.dtype.itemsize
        for k in partial_keys:
            agg_c[k] += _scalar_sum(res.chosen[k])
            agg_d[k] += _scalar_sum(res.default[k])
        report.per_leaf[name] = {
            "alpha": jax.device_get(res.alpha),
            "chosen": {m: _mean_metric(res.chosen, m) for m in
                       ("mse", "sign_rate", "cosine", "delta_l2")},
            "default": {m: _mean_metric(res.default, m) for m in
                        ("mse", "sign_rate", "cosine", "delta_l2")},
            "shape": tuple(w_post.shape),
        }
        if mode == "storage":
            qt = QuantizedTensor(data=res.w_q, scale=res.scale, fmt=qcfg.fmt,
                                 granularity=qcfg.granularity,
                                 block_size=qcfg.block_size, out_dtype=out_dtype)
            report.quantized_bytes += qt.nbytes()
            out_leaves.append(qt)
        else:
            from repro.core.formats import get_format
            report.quantized_bytes += (w_post.size * get_format(qcfg.fmt).bits // 8
                                       + res.scale.size * 4)
            out_leaves.append(res.w_dq.astype(jnp.dtype(out_dtype)))

    agg_cj = {k: jnp.asarray(v) for k, v in agg_c.items()}
    agg_dj = {k: jnp.asarray(v) for k, v in agg_d.items()}
    report.global_chosen = {k: float(v) for k, v in M.metrics_from_partials(agg_cj).items()}
    report.global_default = {k: float(v) for k, v in M.metrics_from_partials(agg_dj).items()}
    return jax.tree_util.tree_unflatten(treedef, out_leaves), report


def _mean_metric(d: dict, m: str) -> float:
    """Per-leaf metric: mean over stacked layers when the leaf was vmapped."""
    return float(jnp.mean(d[m]))


def absmax_tree(params_post: Any, params_base: Any, qcfg: QuantConfig,
                **kw) -> tuple[Any, QuantReport]:
    """AbsMax baseline = Alg. 1 with an empty search (alpha fixed at 1)."""
    import dataclasses
    base_cfg = dataclasses.replace(qcfg, n_coarse=1, n_fine=1, alpha_min=1.0,
                                   alpha_max=1.0, per_block_alpha=False)
    return quantize_tree(params_post, params_base, base_cfg, **kw)
