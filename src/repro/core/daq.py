"""Deprecated model-level entry points — use :mod:`repro.quantize`.

``quantize_tree`` / ``absmax_tree`` were the original tree-walk API.  The
walk (skip policy, partial-sum metric aggregation, storage-vs-dequant
emission) now lives in :func:`repro.quantize.quantize` behind a pluggable
method registry; these shims forward to it with the matching registry
method and will be removed once external callers migrate.  ``QuantReport``
is re-exported from its new home for legacy imports.
"""
from __future__ import annotations

import warnings
from typing import Any

from repro.configs.base import QuantConfig
from repro.quantize.api import QuantReport  # noqa: F401  (legacy re-export)


def _warn(old: str, new: str) -> None:
    warnings.warn(f"repro.core.daq.{old} is deprecated; use "
                  f"repro.quantize.quantize({new})", DeprecationWarning,
                  stacklevel=3)


def quantize_tree(params_post: Any, params_base: Any, qcfg: QuantConfig,
                  *, mode: str = "dequant",
                  out_dtype: str = "float32") -> tuple[Any, QuantReport]:
    """Deprecated: ``repro.quantize.quantize(..., method="daq")``."""
    from repro.quantize import quantize
    _warn("quantize_tree", 'method="daq"')
    return quantize(params_post, params_base, qcfg, mode=mode,
                    out_dtype=out_dtype, method="daq")


def absmax_tree(params_post: Any, params_base: Any, qcfg: QuantConfig,
                **kw) -> tuple[Any, QuantReport]:
    """Deprecated: ``repro.quantize.quantize(..., method="absmax")``."""
    from repro.quantize import quantize
    _warn("absmax_tree", 'method="absmax"')
    return quantize(params_post, params_base, qcfg, method="absmax", **kw)
