"""Low-precision number formats: quantize / dequantize primitives.

The paper instantiates DAQ with FP8 (E4M3).  The DAQ objective is format
agnostic (paper Sec. 2.2), so we also provide FP8 E5M2 and symmetric INT8 /
INT4 — the INT formats are where delta corruption is most visible and are
used by the beyond-paper studies.

All functions are jit-safe, shape-polymorphic and vmap-able.  ``quantize``
maps a float tensor to its low-precision storage representation under a
scale; ``dequantize`` maps it back.  ``qdq = dequantize(quantize(.))`` is the
quantize-dequantize operator :math:`Q_s(W)` from paper Eq. 4.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Format:
    name: str
    qmax: float                  # largest representable magnitude
    storage_dtype: jnp.dtype     # dtype of the stored representation
    is_float: bool
    bits: int


FP8_E4M3 = Format("fp8_e4m3", 448.0, jnp.float8_e4m3fn, True, 8)
FP8_E5M2 = Format("fp8_e5m2", 57344.0, jnp.float8_e5m2, True, 8)
INT8 = Format("int8", 127.0, jnp.int8, False, 8)
# INT4 stored widened in int8 (packing is a storage detail, not a numerics one)
INT4 = Format("int4", 7.0, jnp.int8, False, 4)

FORMATS: dict[str, Format] = {f.name: f for f in (FP8_E4M3, FP8_E5M2, INT8, INT4)}


def get_format(name: str) -> Format:
    if name not in FORMATS:
        raise KeyError(f"unknown format {name!r}; available: {sorted(FORMATS)}")
    return FORMATS[name]


def quantize(w: jnp.ndarray, scale: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """Map ``w`` to low-precision storage under ``scale`` (broadcastable).

    FP8 casts saturate (jax/ml_dtypes overflow to NaN, so we clip first);
    INT formats round-to-nearest-even then clip.
    """
    scaled = (w / scale).astype(jnp.float32)
    if fmt.is_float:
        clipped = jnp.clip(scaled, -fmt.qmax, fmt.qmax)
        return clipped.astype(fmt.storage_dtype)
    rounded = jnp.round(scaled)  # round-half-to-even, matches hardware RTNE
    clipped = jnp.clip(rounded, -fmt.qmax, fmt.qmax)
    return clipped.astype(fmt.storage_dtype)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, fmt: Format,
               out_dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    """Map low-precision storage back to the floating-point domain."""
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


def qdq(w: jnp.ndarray, scale: jnp.ndarray, fmt: Format) -> jnp.ndarray:
    """Quantize-dequantize operator :math:`Q_s(W)` (paper Eq. 4), fp32 out."""
    return dequantize(quantize(w, scale, fmt), scale, fmt)
