"""Scale granularities: per-tensor, per-channel, block-wise.

A weight matrix is always treated as 2-D ``[in_features, out_features]``
(higher-rank weights are reshaped by the caller).  Scales are stored in a
shape that broadcasts against the *blocked view* of the weight:

  tensor  : scalar ()                                 applied to all of W
  channel : [1, out]                                  one scale per output channel
  block   : [in/bs, out/bs]  (broadcast over each     one scale per (bs x bs) block
             bs x bs tile via the blocked view)

``to_blocked`` / ``from_blocked`` convert between ``[I, O]`` and
``[I/bs, bs, O/bs, bs]`` so that a block scale of shape ``[I/bs, 1, O/bs, 1]``
broadcasts elementwise.  Ragged edges are zero-padded; padding never affects
absmax scales (|0| = 0) and is stripped on the way out.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import Format


def pad_to_blocks(w: jnp.ndarray, bs: int) -> tuple[jnp.ndarray, tuple[int, int]]:
    i, o = w.shape
    pi = (-i) % bs
    po = (-o) % bs
    if pi or po:
        w = jnp.pad(w, ((0, pi), (0, po)))
    return w, (i, o)


def to_blocked(w: jnp.ndarray, bs: int) -> jnp.ndarray:
    """[I, O] -> [I/bs, bs, O/bs, bs] (caller must pre-pad)."""
    i, o = w.shape
    return w.reshape(i // bs, bs, o // bs, bs)


def from_blocked(wb: jnp.ndarray, orig: tuple[int, int]) -> jnp.ndarray:
    nb_i, bs, nb_o, _ = wb.shape
    w = wb.reshape(nb_i * bs, nb_o * bs)
    return w[: orig[0], : orig[1]]


def absmax_scale(w: jnp.ndarray, granularity: str, fmt: Format,
                 block_size: int = 128) -> jnp.ndarray:
    """Default AbsMax scale s0 = max|W| / Qmax at the requested granularity.

    Returned shape: tensor -> (); channel -> [1, O]; block -> [I/bs, 1, O/bs, 1]
    (block scales broadcast against the blocked view).
    """
    w = w.astype(jnp.float32)
    eps = jnp.float32(1e-12)
    if granularity == "tensor":
        amax = jnp.max(jnp.abs(w))
    elif granularity == "channel":
        amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)          # [1, O]
    elif granularity == "block":
        wp, _ = pad_to_blocks(w, block_size)
        wb = to_blocked(wp, block_size)
        amax = jnp.max(jnp.abs(wb), axis=(1, 3), keepdims=True)    # [I/bs,1,O/bs,1]
    else:
        raise ValueError(f"unknown granularity {granularity!r}")
    return jnp.maximum(amax, eps) / fmt.qmax


def apply_qdq(w: jnp.ndarray, scale: jnp.ndarray, granularity: str, fmt: Format,
              block_size: int = 128) -> jnp.ndarray:
    """Quantize-dequantize W under scales of the given granularity (fp32 out)."""
    from repro.core.formats import qdq  # local to avoid cycles in docs builds
    w32 = w.astype(jnp.float32)
    if granularity in ("tensor", "channel"):
        return qdq(w32, scale, fmt)
    wp, orig = pad_to_blocks(w32, block_size)
    wb = to_blocked(wp, block_size)
    return from_blocked(qdq(wb, scale, fmt), orig)


def quantize_store(w: jnp.ndarray, scale: jnp.ndarray, granularity: str, fmt: Format,
                   block_size: int = 128) -> jnp.ndarray:
    """Quantize to the storage representation (same layout as W, low dtype)."""
    from repro.core.formats import quantize
    w32 = w.astype(jnp.float32)
    if granularity in ("tensor", "channel"):
        return quantize(w32, scale, fmt)
    wp, orig = pad_to_blocks(w32, block_size)
    wb = to_blocked(wp, block_size)
    qb = quantize(wb, scale, fmt)
    nb_i, bs, nb_o, _ = qb.shape
    q = qb.reshape(nb_i * bs, nb_o * bs)
    return q[: orig[0], : orig[1]]


def dequantize_stored(q: jnp.ndarray, scale: jnp.ndarray, granularity: str, fmt: Format,
                      block_size: int = 128,
                      out_dtype: jnp.dtype = jnp.bfloat16) -> jnp.ndarray:
    """Dequantize a stored representation back to floats."""
    if granularity in ("tensor", "channel"):
        return (q.astype(jnp.float32) * scale).astype(out_dtype)
    qp, orig = pad_to_blocks(q.astype(jnp.float32), block_size)
    qb = to_blocked(qp, block_size)
    wb = qb * scale
    return from_blocked(wb, orig).astype(out_dtype)
