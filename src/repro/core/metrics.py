"""Delta-aware metrics (paper Sec. 2.3).

All metrics take the post-training delta ``dp = W_post - W_base`` and the
quantized delta ``dq = Q_s(W_post) - W_base`` (paper Eqs. 1-2) and return a
scalar.  ``objective`` returns the maximization objective used by the scale
search (``-MSE`` for the reconstruction metric, per paper Table 1 footnote).

The metrics are also exposed in partial-sum form so that block-wise /
channel-wise variants (beyond-paper per-block alpha search) and the Pallas
fused-search kernel can accumulate them in one pass over the weights.
"""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-12


# ---------------------------------------------------------------------------
# Whole-tensor metrics (paper Eqs. 6, 8, 9)
# ---------------------------------------------------------------------------

def mse(dp: jnp.ndarray, dq: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6/7: reconstruction MSE; identical whether computed on deltas or
    on (W_quant, W_post) — the base model cancels (paper Eq. 7)."""
    d = (dq - dp).astype(jnp.float32)
    return jnp.mean(d * d)


def sign_rate(dp: jnp.ndarray, dq: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8: fraction of elements whose delta sign is preserved (sign(0)=0)."""
    sp = jnp.sign(dp.astype(jnp.float32))
    sq = jnp.sign(dq.astype(jnp.float32))
    return jnp.mean((sp == sq).astype(jnp.float32))


def cosine(dp: jnp.ndarray, dq: jnp.ndarray) -> jnp.ndarray:
    """Eq. 9: cosine similarity between the flattened delta vectors."""
    dp = dp.astype(jnp.float32)
    dq = dq.astype(jnp.float32)
    num = jnp.sum(dp * dq)
    den = jnp.sqrt(jnp.sum(dp * dp)) * jnp.sqrt(jnp.sum(dq * dq))
    return num / jnp.maximum(den, EPS)


def delta_l2(dp: jnp.ndarray, dq: jnp.ndarray) -> jnp.ndarray:
    """|| dq - dp ||_2 — the 'Delta-W L2' column of the paper's tables."""
    d = (dq - dp).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(d * d))


def all_metrics(dp: jnp.ndarray, dq: jnp.ndarray) -> dict[str, jnp.ndarray]:
    return {
        "mse": mse(dp, dq),
        "sign_rate": sign_rate(dp, dq),
        "cosine": cosine(dp, dq),
        "delta_l2": delta_l2(dp, dq),
    }


def objective(name: str, dp: jnp.ndarray, dq: jnp.ndarray,
              hybrid_lambda: float = 0.5) -> jnp.ndarray:
    """Scalar maximization objective M (paper Eq. 3)."""
    if name == "mse":
        return -mse(dp, dq)
    if name == "sign":
        return sign_rate(dp, dq)
    if name == "cosine":
        return cosine(dp, dq)
    if name == "hybrid":
        # Beyond-paper: paper Sec 3.5 takeaway 3 suggests a hybrid metric.
        return hybrid_lambda * sign_rate(dp, dq) + (1 - hybrid_lambda) * cosine(dp, dq)
    raise ValueError(f"unknown metric {name!r}")


# ---------------------------------------------------------------------------
# Partial-sum forms: reduce over `axes`, keep the remaining (block) axes.
# Used by the per-block alpha search and mirrored by kernels/scale_search.
# ---------------------------------------------------------------------------

def partial_sums(dp: jnp.ndarray, dq: jnp.ndarray, axes) -> dict[str, jnp.ndarray]:
    import numpy as np
    dp = dp.astype(jnp.float32)
    dq = dq.astype(jnp.float32)
    diff = dq - dp
    sq_err = jnp.sum(diff * diff, axis=axes)
    count = float(np.prod([dp.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
    return {
        "sq_err": sq_err,
        "n_sign_match": jnp.sum((jnp.sign(dp) == jnp.sign(dq)).astype(jnp.float32), axis=axes),
        "dot": jnp.sum(dp * dq, axis=axes),
        "dp_sq": jnp.sum(dp * dp, axis=axes),
        "dq_sq": jnp.sum(dq * dq, axis=axes),
        "count": jnp.full(sq_err.shape, count, jnp.float32),
    }


def objective_from_partials(name: str, p: dict[str, jnp.ndarray],
                            hybrid_lambda: float = 0.5) -> jnp.ndarray:
    """Per-block objective from partial sums (same semantics as `objective`
    restricted to a block)."""
    if name == "mse":
        return -p["sq_err"] / jnp.maximum(p["count"], 1.0)
    if name == "sign":
        return p["n_sign_match"] / jnp.maximum(p["count"], 1.0)
    cos = p["dot"] / jnp.maximum(jnp.sqrt(p["dp_sq"]) * jnp.sqrt(p["dq_sq"]), EPS)
    if name == "cosine":
        return cos
    if name == "hybrid":
        sr = p["n_sign_match"] / jnp.maximum(p["count"], 1.0)
        return hybrid_lambda * sr + (1 - hybrid_lambda) * cos
    raise ValueError(f"unknown metric {name!r}")


def combine_partials(parts: list[dict[str, jnp.ndarray]]) -> dict[str, jnp.ndarray]:
    """Sum partial sums across tensors (for model-level aggregate metrics)."""
    out: dict[str, jnp.ndarray] = {}
    for key in ("sq_err", "n_sign_match", "dot", "dp_sq", "dq_sq", "count"):
        out[key] = sum(jnp.sum(p[key]) for p in parts)
    return out


def metrics_from_partials(p: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
    return {
        "mse": p["sq_err"] / jnp.maximum(p["count"], 1.0),
        "sign_rate": p["n_sign_match"] / jnp.maximum(p["count"], 1.0),
        "cosine": p["dot"] / jnp.maximum(jnp.sqrt(p["dp_sq"]) * jnp.sqrt(p["dq_sq"]), EPS),
        "delta_l2": jnp.sqrt(p["sq_err"]),
    }
