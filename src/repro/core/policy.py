"""Which parameter leaves get quantized.

DAQ (like the FP8 deployment it targets) quantizes matmul weights.  Norm
scales, biases, router logit weights, SSM time-constants / A_log / conv
filters and the token embedding table stay in high precision — they are tiny
and numerically sensitive.  Patterns are configurable via
``QuantConfig.skip_patterns``.
"""
from __future__ import annotations

from typing import Any

import jax

DEFAULT_SKIP = ("norm", "bias", "router", "a_log", "dt_bias", "d_skip", "conv", "embed")


def path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def should_quantize(path: str, leaf: Any, skip_patterns=DEFAULT_SKIP,
                    min_dim: int = 16) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    low = path.lower()
    if any(pat in low for pat in skip_patterns):
        return False
    if min(leaf.shape[-2:]) < min_dim:
        return False
    return True
