"""Coarse-to-fine scale search (paper Algorithm 1) + beyond-paper variants.

The paper-faithful search optimizes ONE alpha multiplier per weight tensor
(applied on top of the per-granularity AbsMax default scales s0) via a coarse
uniform grid over [alpha_min, alpha_max] followed by a fine grid around the
best coarse candidate.  alpha = 1 (the AbsMax default) is always the initial
incumbent (Alg. 1 lines 4-6), so the search never returns a candidate that
scores worse than AbsMax *on the chosen metric*.

Beyond-paper extension (``per_block_alpha=True``): an independent alpha per
block / channel, selected on a dense grid by the per-block objective.  For
SignRate and MSE the objective is separable across blocks, so the per-block
argmax is the *global* optimum over the per-block candidate grid — strictly
at least as good as any shared alpha on the same grid.  For Cosine the
per-block selection optimizes block-local cosine (documented in DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core import metrics as M
from repro.core.formats import get_format
from repro.core.granularity import (absmax_scale, apply_qdq, pad_to_blocks,
                                    quantize_store, to_blocked)


@dataclass
class SearchResult:
    """Result of quantizing one weight tensor."""
    alpha: jnp.ndarray          # chosen multiplier(s): scalar or per-block
    scale: jnp.ndarray          # final scale(s) = alpha * s0
    w_q: jnp.ndarray            # storage representation (fp8/int8), layout of W
    w_dq: jnp.ndarray           # dequantized weights Q_s(W_post), fp32
    chosen: dict                # metrics + partial sums at chosen alpha
    default: dict               # metrics + partial sums at alpha=1 (AbsMax)
    eq_scale: jnp.ndarray | None = None  # per-in-channel equalization vector
                                         # (SmoothQuant/AWQ); w_q stores W*s


jax.tree_util.register_dataclass(
    SearchResult,
    data_fields=["alpha", "scale", "w_q", "w_dq", "chosen", "default",
                 "eq_scale"],
    meta_fields=[],
)


def _candidate_grid(qcfg: QuantConfig) -> jnp.ndarray:
    """Dense grid used by the per-block variant (coarse+fine budget)."""
    n = qcfg.n_coarse + qcfg.n_fine
    return jnp.linspace(qcfg.alpha_min, qcfg.alpha_max, n)


def _eval_alpha(alpha, w_post, dp, w_base, s0, qcfg: QuantConfig):
    fmt = get_format(qcfg.fmt)
    wq = apply_qdq(w_post, alpha * s0, qcfg.granularity, fmt, qcfg.block_size)
    dq = wq - w_base
    return M.objective(qcfg.metric, dp, dq, qcfg.hybrid_lambda)


@partial(jax.jit, static_argnames=("qcfg",))
def search_scale(w_post: jnp.ndarray, w_base: jnp.ndarray,
                 qcfg: QuantConfig) -> SearchResult:
    """Paper Algorithm 1 on a single 2-D weight (jit-compiled).

    Dispatches to the per-block variant when ``qcfg.per_block_alpha`` and to
    the fused one-HBM-pass Pallas sweep when ``qcfg.use_fused_kernel``
    (block fp8 only; same argmax by construction — tests assert equality).
    """
    if qcfg.per_block_alpha:
        return _search_per_block(w_post, w_base, qcfg)
    if qcfg.use_fused_kernel and qcfg.granularity == "block" \
            and qcfg.fmt == "fp8_e4m3":
        return _search_fused(w_post, w_base, qcfg)

    fmt = get_format(qcfg.fmt)
    w_post = w_post.astype(jnp.float32)
    w_base = w_base.astype(jnp.float32)
    dp = w_post - w_base
    s0 = absmax_scale(w_post, qcfg.granularity, fmt, qcfg.block_size)

    eval_fn = lambda a: _eval_alpha(a, w_post, dp, w_base, s0, qcfg)

    # --- init: alpha = 1 (Alg. 1 lines 4-6) ---
    best_alpha = jnp.float32(1.0)
    best_m = eval_fn(best_alpha)

    # --- coarse stage (lines 7-15) ---
    coarse = jnp.linspace(qcfg.alpha_min, qcfg.alpha_max, qcfg.n_coarse)
    coarse_m = jax.lax.map(eval_fn, coarse)
    c_idx = jnp.argmax(coarse_m)
    take_c = coarse_m[c_idx] > best_m
    best_alpha = jnp.where(take_c, coarse[c_idx], best_alpha)
    best_m = jnp.maximum(coarse_m[c_idx], best_m)

    # --- fine stage (lines 16-24) ---
    delta = qcfg.resolved_fine_delta()
    lo = jnp.maximum(qcfg.alpha_min, best_alpha - delta)
    hi = jnp.minimum(qcfg.alpha_max, best_alpha + delta)
    fine = jnp.linspace(lo, hi, qcfg.n_fine)
    fine_m = jax.lax.map(eval_fn, fine)
    f_idx = jnp.argmax(fine_m)
    take_f = fine_m[f_idx] > best_m
    best_alpha = jnp.where(take_f, fine[f_idx], best_alpha)
    best_m = jnp.maximum(fine_m[f_idx], best_m)

    return _finalize(w_post, w_base, dp, best_alpha, s0, qcfg)


def metrics_and_partials(dp, dq):
    """Whole-tensor metrics + full-reduction partial sums for (dp, dq).

    The common currency of ``SearchResult.chosen`` / ``.default`` across all
    registered quantization methods — ``repro.quantize`` aggregates the
    partial sums into exact global model metrics.
    """
    axes = tuple(range(dp.ndim))
    out = dict(M.all_metrics(dp, dq))
    out.update(M.partial_sums(dp, dq, axes))
    return out


def _finalize(w_post, w_base, dp, alpha, s0, qcfg: QuantConfig) -> SearchResult:
    fmt = get_format(qcfg.fmt)
    scale = alpha * s0
    w_dq = apply_qdq(w_post, scale, qcfg.granularity, fmt, qcfg.block_size)
    w_q = quantize_store(w_post, scale, qcfg.granularity, fmt, qcfg.block_size)
    chosen = metrics_and_partials(dp, w_dq - w_base)
    w_dq0 = apply_qdq(w_post, s0, qcfg.granularity, fmt, qcfg.block_size)
    default = metrics_and_partials(dp, w_dq0 - w_base)
    return SearchResult(alpha=alpha, scale=scale, w_q=w_q, w_dq=w_dq,
                        chosen=chosen, default=default)


# ---------------------------------------------------------------------------
# Fused-kernel search: Alg. 1 with the Pallas one-pass candidate sweep.
# ---------------------------------------------------------------------------

def _search_fused(w_post, w_base, qcfg: QuantConfig) -> SearchResult:
    """Same coarse->fine argmax as `search_scale`, but each stage evaluates
    ALL candidates in ONE pass over the weights (kernels/scale_search) —
    ~8x less HBM traffic than re-reading W per candidate (see §Perf)."""
    from repro.kernels.scale_search import ops as K

    w_post = w_post.astype(jnp.float32)
    w_base = w_base.astype(jnp.float32)
    dp = w_post - w_base
    s0 = absmax_scale(w_post, "block", get_format(qcfg.fmt), qcfg.block_size)

    def stage_best(alphas):
        parts = K.sweep(w_post, w_base, alphas, block_size=qcfg.block_size)
        objs = K.objective_values(parts, qcfg.metric, qcfg.hybrid_lambda)
        idx = jnp.argmax(objs)
        return alphas[idx], objs[idx]

    # stage 1: incumbent alpha=1 rides along with the coarse grid
    coarse = jnp.concatenate([jnp.float32([1.0]),
                              jnp.linspace(qcfg.alpha_min, qcfg.alpha_max,
                                           qcfg.n_coarse)])
    best_alpha, _ = stage_best(coarse)
    # stage 2: fine grid around the best candidate (+ incumbent)
    delta = qcfg.resolved_fine_delta()
    lo = jnp.maximum(qcfg.alpha_min, best_alpha - delta)
    hi = jnp.minimum(qcfg.alpha_max, best_alpha + delta)
    fine = jnp.concatenate([best_alpha[None],
                            jnp.linspace(lo, hi, qcfg.n_fine)])
    best_alpha, _ = stage_best(fine)
    return _finalize(w_post, w_base, dp, best_alpha, s0, qcfg)


# ---------------------------------------------------------------------------
# Beyond-paper: independent alpha per block / channel on a dense grid.
# ---------------------------------------------------------------------------

def _search_per_block(w_post, w_base, qcfg: QuantConfig) -> SearchResult:
    fmt = get_format(qcfg.fmt)
    w_post = w_post.astype(jnp.float32)
    w_base = w_base.astype(jnp.float32)
    dp = w_post - w_base
    s0 = absmax_scale(w_post, qcfg.granularity, fmt, qcfg.block_size)
    grid = jnp.concatenate([jnp.float32([1.0]), _candidate_grid(qcfg)])

    if qcfg.granularity == "channel":
        reduce_axes = (0,)
        def per_cand(a):
            wq = apply_qdq(w_post, a * s0, "channel", fmt)
            p = M.partial_sums(dp, wq - w_base, reduce_axes)
            return M.objective_from_partials(qcfg.metric, p, qcfg.hybrid_lambda)
        objs = jax.lax.map(per_cand, grid)              # [n_cand, 1, O]
        idx = jnp.argmax(objs, axis=0)                  # [1, O]
        alpha = grid[idx]                               # [1, O]
    elif qcfg.granularity == "block":
        bs = qcfg.block_size
        wp, _ = pad_to_blocks(w_post, bs)
        wbse, _ = pad_to_blocks(w_base, bs)
        dpb = to_blocked(wp, bs) - to_blocked(wbse, bs)
        def per_cand(a):
            wqb = to_blocked(wp, bs)
            from repro.core.formats import qdq as _qdq
            wqb = _qdq(wqb, a * s0, fmt)
            p = M.partial_sums(dpb, wqb - to_blocked(wbse, bs), (1, 3))
            return M.objective_from_partials(qcfg.metric, p, qcfg.hybrid_lambda)
        objs = jax.lax.map(per_cand, grid)              # [n_cand, I/bs, O/bs]
        idx = jnp.argmax(objs, axis=0)                  # [I/bs, O/bs]
        alpha = grid[idx][:, None, :, None]             # broadcastable vs blocked view
    else:  # tensor granularity: per-block == shared
        reduce_axes = None
        def per_cand(a):
            wq = apply_qdq(w_post, a * s0, "tensor", fmt)
            return M.objective(qcfg.metric, dp, wq - w_base, qcfg.hybrid_lambda)
        objs = jax.lax.map(per_cand, grid)
        alpha = grid[jnp.argmax(objs)]

    return _finalize(w_post, w_base, dp, alpha, s0, qcfg)
