from repro.data.synthetic import (LanguageSpec, bigram_logits, eval_scores,
                                  modality_extras, sample_batch, style_logits,
                                  style_permutation, train_batch)

__all__ = ["LanguageSpec", "bigram_logits", "eval_scores", "modality_extras",
           "sample_batch", "style_logits", "style_permutation", "train_batch"]
