"""Deterministic synthetic data pipeline.

Two corpora drive the DAQ reproduction (DESIGN.md §7):

* **Base corpus** — a fixed random bigram language: each token has a small
  set of plausible successors with Zipf-like weights.  A model can learn it
  to a measurable next-token accuracy ("General" capability).
* **Stylized corpus** — the same language with a distinctive *style*: a
  STYLE_MARKER token is emitted at every position ``t % style_period ==
  style_period-1`` (ordinary positions keep the base bigram, optionally the
  permuted table when ``hard_style``).  SFT on this corpus imparts a
  small-ΔW behavioural change — exactly the paper's setting of post-training
  knowledge that quantization may erase.

Scores (both in [0, 2], mirroring the paper's rubric scale):
  Style   = 2 x mean(argmax-correct at style positions wrt the style process)
  General = 2 x mean(argmax-correct next-token on base-corpus holdout)

Everything is generated on the fly from a seed: the stream is stateless and
shardable — batch ``step`` on host ``h`` is a pure function of
``(seed, step, h)``, which is what makes every training step replayable
after a fault (launch/train.py restart loop).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LanguageSpec:
    vocab: int
    branching: int = 16
    style_period: int = 8
    seed: int = 1234
    hard_style: bool = False   # also permute the bigram table under style

    @property
    def style_marker(self) -> int:
        return self.vocab - 1


def bigram_logits(spec: LanguageSpec) -> jnp.ndarray:
    """Fixed random bigram logit table [V, V]; each row has ``branching``
    plausible successors with Zipf weights, rest ~ -inf."""
    rng = np.random.RandomState(spec.seed)
    V, K = spec.vocab, spec.branching
    logits = np.full((V, V), -30.0, np.float32)
    weights = np.log(1.0 / np.arange(1, K + 1))  # Zipf
    for v in range(V):
        # successors exclude vocab-1: it is the reserved STYLE_MARKER
        succ = rng.choice(V - 1, size=K, replace=False)
        logits[v, succ] = weights
    return jnp.asarray(logits)


def style_permutation(spec: LanguageSpec) -> jnp.ndarray:
    """Fixed derangement-ish permutation defining the style bigram table."""
    rng = np.random.RandomState(spec.seed + 1)
    return jnp.asarray(rng.permutation(spec.vocab))


def style_logits(spec: LanguageSpec) -> jnp.ndarray:
    """Style table: P_style[a] = P_base[perm[a]] (successor shift)."""
    return bigram_logits(spec)[style_permutation(spec)]


@partial(jax.jit, static_argnames=("spec", "batch", "seq", "style"))
def sample_batch(key, spec: LanguageSpec, batch: int, seq: int,
                 style: bool = False) -> jnp.ndarray:
    """Sample [batch, seq+1] token sequences from the (styled) language."""
    base = bigram_logits(spec)
    table = style_logits(spec) if (style and spec.hard_style) else base
    marker = spec.style_marker

    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, spec.vocab - 1)

    def step(tok, inp):
        t, kt = inp
        logits = table[tok]
        nxt = jax.random.categorical(kt, logits)
        if style:
            is_marker = (t % spec.style_period) == (spec.style_period - 1)
            nxt = jnp.where(is_marker, marker, nxt)
            # after a marker, continue from the pre-marker token's successors
            tok_next = jnp.where(is_marker, tok, nxt)
        else:
            tok_next = nxt
        return tok_next, nxt

    ts = jnp.arange(1, seq + 1)
    keys = jax.random.split(k1, seq)
    _, rest = jax.lax.scan(step, first, (ts, keys))
    return jnp.concatenate([first[:, None], rest.T], axis=1)


def train_batch(spec: LanguageSpec, seed: int, step: int, batch: int,
                seq: int, *, style=False, host: int = 0) -> dict:
    """Batch ``step`` of the deterministic stream: {"tokens","labels"}.

    ``style``: False (base corpus), True (pure stylized), or "mixed" —
    half stylized / half base rows, the realistic SFT recipe that retains
    general capability while teaching the style."""
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), host)
    if style == "mixed":
        k1, k2 = jax.random.split(key)
        h = batch // 2
        t1 = sample_batch(k1, spec, h, seq, True)
        t2 = sample_batch(k2, spec, batch - h, seq, False)
        toks = jnp.concatenate([t1, t2], axis=0)
    else:
        toks = sample_batch(key, spec, batch, seq, bool(style))
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def modality_extras(cfg, batch: int, seq: int, seed: int, step: int) -> dict:
    """Stub frontend tensors for vlm / encdec batches (assignment spec)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 77), step)
    if cfg.family == "vlm":
        return {"image_embeds": 0.02 * jax.random.normal(
            key, (batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)}
    if cfg.family == "encdec":
        frames = min(seq, cfg.enc_frames_cap)
        return {"frames": 0.02 * jax.random.normal(
            key, (batch, frames, cfg.d_model), jnp.bfloat16)}
    return {}


# ---------------------------------------------------------------------------
# Evaluation: Style / General scores (paper's rubric proxies)
# ---------------------------------------------------------------------------

def eval_scores(model, params, spec: LanguageSpec, *, batch: int = 16,
                seq: int = 128, seed: int = 999, extras_fn=None) -> dict:
    """Rubric-proxy scores in [0, 2] (paper §3.1 scale).

    * Style   — on stylized prompts: mean of (a) marker accuracy at marker
      positions and (b) mode accuracy of the *style* bigram at ordinary
      positions (the model's argmax vs the style table's most likely
      successor — deterministic ground truth, so a perfectly styled model
      scores 2.0 regardless of sampling entropy).
    * General — mode accuracy of the *base* bigram on base-corpus prompts.
    """
    kg = jax.random.PRNGKey(seed)
    kb, ks = jax.random.split(kg)
    extras = extras_fn(batch, seq) if extras_fn else {}

    base_mode = jnp.argmax(bigram_logits(spec), axis=-1)       # [V]
    style_tab = style_logits(spec) if spec.hard_style else bigram_logits(spec)
    style_mode = jnp.argmax(style_tab, axis=-1)

    def argmax_preds(tokens):
        b = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:], **extras}
        logits = _full_logits(model, params, b)
        return jnp.argmax(logits, axis=-1), b["tokens"]

    # General: mode accuracy on the base corpus
    base_toks = sample_batch(kb, spec, batch, seq, style=False)
    pred, prev = argmax_preds(base_toks)
    gen_acc = float(jnp.mean(pred == base_mode[prev]))
    general = 2.0 * gen_acc

    # Style: markers + style-bigram modes on stylized prompts
    st_toks = sample_batch(ks, spec, batch, seq, style=True)
    pred, prev = argmax_preds(st_toks)
    pos = jnp.arange(pred.shape[1])[None, :]
    is_marker = jnp.broadcast_to(
        ((pos + 1) % spec.style_period) == (spec.style_period - 1),
        pred.shape)
    prev_is_marker = prev == spec.style_marker
    marker_acc = float(jnp.sum((pred == spec.style_marker) & is_marker)
                       / jnp.maximum(jnp.sum(is_marker), 1))
    ordinary = (~is_marker) & (~prev_is_marker)
    bigram_acc = float(jnp.sum((pred == style_mode[prev]) & ordinary)
                       / jnp.maximum(jnp.sum(ordinary), 1))
    style = 2.0 * (0.5 * marker_acc + 0.5 * bigram_acc)

    return {"style": style, "general": general,
            "style_marker_acc": marker_acc, "style_bigram_acc": bigram_acc,
            "general_acc": gen_acc}


def _full_logits(model, params, batch):
    """[B, S, V] logits (small-scale eval only)."""
    from repro.models.common import apply_norm, embed_tokens, lm_logits
    from repro.models.lm import layer_plan, run_stack_train
    cfg = model.cfg
    x = embed_tokens(params["embed"], batch["tokens"])
    if cfg.family == "encdec":
        from repro.models.lm import _build_encdec  # noqa: F401  (same path)
        from repro.models import lm as _lm
        mem = x * 0  # placeholder; replaced below
        # encode frames
        enc_specs = [("enc_attn", "mlp")]
        m = batch["frames"].astype(x.dtype)
        m, _ = run_stack_train(params["enc_stack"], m, cfg, enc_specs,
                               remat="none")
        mem = apply_norm(params["enc_norm"], m, cfg.norm_eps)
        x, _ = run_stack_train(params["stack"], x, cfg,
                               [("attn_cross", "mlp")], memory=mem,
                               remat="none")
    else:
        prefix_specs, n_prefix, specs, _ = layer_plan(cfg)
        mem = batch.get("image_embeds")
        if mem is not None:
            mem = mem.astype(x.dtype)
        if n_prefix:
            x, _ = run_stack_train(params["prefix"], x, cfg, prefix_specs,
                                   memory=mem, remat="none")
        x, _ = run_stack_train(params["stack"], x, cfg, specs, memory=mem,
                               remat="none")
    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params["embed"], x).astype(jnp.float32)
