"""Device-resident serving engine (continuous batching, batched prefill,
real sampling, opt-in sharded serving).

    from repro.engine import Engine, EngineConfig, SamplingParams

    eng = Engine(model, params, slots=8, cache_len=512, k_steps=8,
                 sampling=SamplingParams(greedy=False, temperature=0.8,
                                         top_k=40))
    outputs = eng.serve(requests, gen_tokens=64)

See engine.py (host/device split), scheduler.py (slot state + K-step
dispatch, in-scan chunked prefill), paged.py (paged KV cache: block pool,
block tables, device free-list, refcounted sharing + copy-on-write —
``Engine(..., paged=True)``), prefix.py (host chained-hash prompt-block
index — ``Engine(..., paged=True, prefix_cache=True)``), spec.py (self-speculative
decoding: quantized-draft rounds verified by the full-precision model —
``Engine(..., paged=True, n_spec=4, draft_params=qtree)``), sampler.py
(greedy / temperature / top-k / top-p), legacy.py (the old host-driven
loop, kept as benchmark baseline).
"""
from repro.engine.engine import Engine, EngineConfig
from repro.engine.legacy import serve_host_loop, single_slot_prefill
from repro.engine.paged import (admit_slot, alloc_admit, alloc_span,
                                alloc_step, blocks_for, gather_blocks,
                                init_block_state, release_refs,
                                release_slots, span_targets)
from repro.engine.prefix import PrefixIndex, chain_hashes
from repro.engine.sampler import SamplingParams, probs, sample, warp_logits
from repro.engine.scheduler import (init_slot_state, make_decode_dispatch,
                                    make_decode_step)
from repro.engine.spec import (DepthController, greedy_accept,
                               make_spec_dispatch, rejection_accept)

__all__ = [
    "Engine", "EngineConfig", "SamplingParams", "sample", "probs",
    "warp_logits",
    "init_slot_state", "make_decode_dispatch", "make_decode_step",
    "make_spec_dispatch", "greedy_accept", "rejection_accept",
    "DepthController",
    "serve_host_loop", "single_slot_prefill",
    "admit_slot", "alloc_admit", "alloc_span", "alloc_step", "blocks_for",
    "gather_blocks", "init_block_state", "release_refs", "release_slots",
    "span_targets", "PrefixIndex", "chain_hashes",
]
