"""Device-resident serving engine: continuous batching with on-device
scheduling, batched multi-slot prefill, real sampling, opt-in sharding.

The host keeps only what it must (the request queue and a mirror of each
slot's budget, maintained from the dispatch results it already fetched —
no extra syncs); everything per-token lives on device:

* **decode** — one jitted dispatch runs ``k_steps`` decode steps under
  ``lax.scan`` (scheduler.make_decode_dispatch); the host syncs once per
  dispatch to drain the emitted-token grid.
* **prefill** — all free slots' pending prompts go through batched
  ``model.prefill`` calls (a single right-padded call when the model
  supports it, else one call per distinct prompt length) and their cache
  rows are scattered into the live cache in one jitted update.  When the
  whole pool is being (re)filled in one equal-length batch the returned
  cache simply *replaces* the live one — the scatter-free path.
* **sampling** — greedy / temperature / top-k via engine.sampler with a
  per-step threaded PRNG key (the old host loop's ``greedy=False`` was
  silently argmax).
* **sharding** — pass ``mesh=`` to place params with
  ``launch.sharding.params_shardings`` (quantized ``wq/data`` / ``wq/scale``
  leaves inherit the dense weight's layout by tree path) and the decode
  cache with ``cache_shardings``; all jitted steps then run GSPMD-partitioned.
* **paged KV cache** — ``paged=True`` swaps the per-slot contiguous cache
  for a global block pool with per-slot block tables and a device-resident
  free-list (engine/paged.py): memory tracks live tokens instead of
  ``slots * cache_len``, admission reserves each request's lifetime worst
  case against the pool (FIFO; requests wait when the head doesn't fit),
  and blocks recycle inside the K-step scan as slots drain.  Greedy
  outputs stay token-exact vs the contiguous cache.

Right-padded prefill is only exact when a row's hidden states cannot depend
on positions after it or on other tokens' presence: pure causal attention
qualifies; SWA ring caches (slot = position % window would index pad
positions), Mamba state accumulation, and capacity-routed MoE (pad tokens
compete for per-expert capacity and can displace real tokens) do not —
those configs fall back to equal-length grouping automatically.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.engine import paged as P
from repro.engine.sampler import SamplingParams, sample
from repro.engine.scheduler import init_slot_state, make_decode_dispatch
from repro.models.lm import Model

_BKEYS = P.BSTATE_KEYS


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 2          # size of the continuous-batching pool
    cache_len: int = 256    # decode cache capacity per slot
    k_steps: int = 8        # decode steps per dispatch (1 host sync each)
    sampling: SamplingParams = field(default_factory=SamplingParams)
    seed: int = 0
    paged: bool = False     # paged KV cache (block pool + block tables)
    block_size: int = 16    # tokens per KV block (paged only)
    num_blocks: int = 0     # pool size; 0 -> slots * ceil(cap / block_size)
                            # (capacity parity with the contiguous cache)


class Engine:
    """Continuous-batching serving engine over a built :class:`Model`."""

    def __init__(self, model: Model, params, cfg: EngineConfig | None = None,
                 *, mesh=None, **kw):
        if cfg is None:
            cfg = EngineConfig(**kw)
        elif kw:
            raise TypeError("pass either cfg= or keyword fields, not both")
        if model.cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "Engine drives LM-style models; vlm/encdec need modality "
                "inputs (see examples/)")
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        # right-padded prefill is exact only for pure-causal-attention
        # stacks with non-ring caches AND no cross-token coupling: MoE is
        # excluded because pad tokens join capacity-limited routing and can
        # displace real tokens' expert assignments (see module docstring)
        mcfg = model.cfg
        self._can_pad = (mcfg.family == "dense"
                         and not mcfg.sliding_window)
        self.params = self._place_params(params) if mesh is not None else params

        sp, K = cfg.sampling, cfg.k_steps
        if K < 1:
            raise ValueError(f"k_steps must be >= 1, got {K}")
        if cfg.paged:
            window = mcfg.sliding_window
            cap = min(cfg.cache_len, window) if window else cfg.cache_len
            if window and cap != window:
                raise ValueError(
                    f"paged SWA serving needs cache_len >= sliding_window "
                    f"({cfg.cache_len} < {window})")
            self._mb = P.blocks_for(cap, cfg.block_size)  # blocks per slot
            self._num_blocks = cfg.num_blocks or cfg.slots * self._mb
        self._dispatch = jax.jit(
            make_decode_dispatch(model, sp, K, paged=cfg.paged),
            donate_argnums=(1, 2))
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0, 1))
        self._scatter_paged = jax.jit(self._scatter_paged_impl,
                                      donate_argnums=(0, 1))
        # paged prefill sizes the part cache to the admitted group (block-
        # aligned prompt rows), so admission cost tracks prompt length; the
        # contiguous path always materializes cache_len rows.
        self._prefill_full = jax.jit(
            lambda p, toks, cl: model.prefill(p, {"tokens": toks},
                                              cache_len=cl),
            static_argnums=(2,))
        self._prefill_padded = jax.jit(
            lambda p, toks, lens, cl: model.prefill(p, {"tokens": toks},
                                                    cache_len=cl,
                                                    lengths=lens),
            static_argnums=(3,))

    # -- sharded placement --------------------------------------------------

    def _place_params(self, params):
        from repro.launch.sharding import params_shardings
        shard = params_shardings(jax.eval_shape(lambda: params),
                                 self.model.cfg, self.mesh)
        return jax.device_put(params, shard)

    def _place_cache(self, cache):
        from repro.launch.sharding import cache_shardings
        shard = cache_shardings(jax.eval_shape(lambda: cache),
                                self.model.cfg, self.mesh)
        return jax.device_put(cache, shard)

    # -- batched prefill + single-scatter admission -------------------------

    @staticmethod
    def _scatter_impl(cache, state, part_cache, slots, first, remaining0):
        """Scatter ``part_cache`` rows (batch axis 1 under the period axis)
        into the live cache at ``slots`` and arm the slot state — one jitted
        update for the whole admitted group."""
        def sc(full, part):
            return full.at[:, slots].set(part.astype(full.dtype))

        new = dict(cache)
        new["stack"] = jax.tree.map(sc, cache["stack"], part_cache["stack"])
        if "prefix" in cache:
            new["prefix"] = jax.tree.map(sc, cache["prefix"],
                                         part_cache["prefix"])
        new["lengths"] = cache["lengths"].at[slots].set(
            part_cache["lengths"])
        state = {
            "cur": state["cur"].at[slots, 0].set(first),
            "active": state["active"].at[slots].set(remaining0 > 0),
            "remaining": state["remaining"].at[slots].set(remaining0),
        }
        return new, state

    @staticmethod
    def _scatter_paged_impl(cache, state, part_cache, slots, lens, first,
                            remaining0, counts):
        """Admit one prefilled group into the paged cache: release the
        target slots' stale blocks, allocate ``counts[i]`` fresh blocks per
        slot, scatter the part cache's K/V rows block-wise into the pools
        (rows past a slot's true need land in the trash block) and dense
        (SSM) leaves slot-wise — one jitted update for the whole group."""
        B = state["active"].shape[0]
        bstate = {k: cache[k] for k in _BKEYS}
        done = jnp.zeros((B,), bool).at[slots].set(True)
        bstate = P.release_slots(bstate, done)

        # static block geometry from the part tree (absent for pure-SSM)
        nbl = 0
        for lcache in part_cache["stack"].values():
            if "k" in lcache:
                bs = next(l for l in cache["stack"].values()
                          if "pk" in l)["pk"].shape[2]
                nbl = lcache["k"].shape[2] // bs
                break
        if nbl:
            bstate, wids = P.alloc_admit(bstate, slots, counts, nbl)
        # a slot that owes no decode steps must not write or grow; its
        # blocks are released again right below (the KV is never read —
        # the single output token came straight from the prefill logits)
        bstate["slot_active"] = bstate["slot_active"].at[slots].set(
            remaining0 > 0)
        bstate = P.release_slots(bstate, done & (remaining0 <= 0))

        def scatter_group(pool_group, part_group):
            new_group = {}
            for lkey, lcache in pool_group.items():
                pl, nl = part_group[lkey], {}
                for name, leaf in lcache.items():
                    if name in ("pk", "pv"):
                        src = pl["k" if name == "pk" else "v"]
                        n, g, L = src.shape[:3]
                        blocks = src.reshape(n, g * nbl, L // nbl,
                                             *src.shape[3:])
                        nl[name] = leaf.at[:, wids.reshape(-1)].set(
                            blocks.astype(leaf.dtype))
                    else:  # contiguous per-slot leaf (SSM state)
                        nl[name] = leaf.at[:, slots].set(
                            pl[name].astype(leaf.dtype))
                new_group[lkey] = nl
            return new_group

        new = dict(cache)
        new.update(bstate)
        new["stack"] = scatter_group(cache["stack"], part_cache["stack"])
        if "prefix" in cache:
            new["prefix"] = scatter_group(cache["prefix"],
                                          part_cache["prefix"])
        new["lengths"] = cache["lengths"].at[slots].set(lens)
        state = {
            "cur": state["cur"].at[slots, 0].set(first),
            "active": state["active"].at[slots].set(remaining0 > 0),
            "remaining": state["remaining"].at[slots].set(remaining0),
        }
        return new, state

    def _group_cache_len(self, Lmax: int) -> int:
        """Prefill cache rows for one admitted group.  Contiguous: always
        the full per-slot capacity.  Paged: SWA pages the whole ring (the
        ring cap must match the decode cap), dense pages just the block-
        aligned prompt rows — admission memory tracks the prompt."""
        cfg = self.cfg
        if not cfg.paged:
            return cfg.cache_len
        if self.model.cfg.sliding_window:
            return cfg.cache_len
        return min(P.blocks_for(Lmax, cfg.block_size), self._mb) \
            * cfg.block_size

    def _admit(self, cache, state, free_slots, prompts, gen_tokens, key):
        """Prefill ``prompts`` into ``free_slots``.  Returns (cache, state,
        first_tokens host list, n_prefill_calls)."""
        cfg = self.cfg
        B = cfg.slots
        lens = [int(p.shape[0]) for p in prompts]
        if len(set(lens)) == 1:
            groups = [list(range(len(prompts)))]
        elif self._can_pad:
            groups = [list(range(len(prompts)))]
        else:  # ring/SSM caches: exact per-length batches
            by_len: dict[int, list[int]] = {}
            for i, L in enumerate(lens):
                by_len.setdefault(L, []).append(i)
            groups = list(by_len.values())

        firsts: dict[int, int] = {}
        rem0 = jnp.int32(gen_tokens - 1)
        for g in groups:
            key, sub = jax.random.split(key)
            Lmax = max(lens[i] for i in g)
            cl = self._group_cache_len(Lmax)
            toks = jnp.stack([
                jnp.pad(prompts[i], (0, Lmax - lens[i])) for i in g
            ]).astype(jnp.int32)
            if all(lens[i] == Lmax for i in g):
                logits, part = self._prefill_full(self.params, toks, cl)
            else:
                glens = jnp.asarray([lens[i] for i in g], jnp.int32)
                logits, part = self._prefill_padded(self.params, toks,
                                                    glens, cl)
            first = sample(logits, sub, self.cfg.sampling)
            g_slots = [free_slots[i] for i in g]
            if cfg.paged:
                if self.model.cfg.sliding_window:
                    counts = jnp.full((len(g),), self._mb, jnp.int32)
                else:
                    # clamp to per-slot capacity: an over-long prompt only
                    # keeps its first cap rows (the contiguous cache drops
                    # the overflow the same way) — without the clamp the
                    # allocator would debit blocks the scatter never places
                    counts = jnp.asarray(
                        [min(P.blocks_for(lens[i], cfg.block_size),
                             self._mb) for i in g], jnp.int32)
                cache, state = self._scatter_paged(
                    cache, state, part, jnp.asarray(g_slots, jnp.int32),
                    jnp.asarray([lens[i] for i in g], jnp.int32),
                    first, rem0, counts)
            elif len(g) == B and g_slots == list(range(B)):
                # scatter-free: the prefill result IS the new cache
                if self.mesh is not None:
                    part = self._place_cache(part)
                cache = part
                state = {"cur": first[:, None].astype(jnp.int32),
                         "active": jnp.broadcast_to(rem0 > 0, (B,)),
                         "remaining": jnp.broadcast_to(rem0, (B,))}
            else:
                cache, state = self._scatter(
                    cache, state, part, jnp.asarray(g_slots, jnp.int32),
                    first, rem0)
            for i, t in zip(g, jax.device_get(first)):
                firsts[i] = int(t)
        return cache, state, [firsts[i] for i in range(len(prompts))], \
            len(groups)

    # -- serve --------------------------------------------------------------

    def _blocks_needed(self, prompt_len: int, gen_tokens: int) -> int:
        """Worst-case pool blocks one request can ever hold: SWA rings page
        the whole window; dense requests write ``prompt + gen - 1`` cache
        rows over their lifetime (capacity-clamped, like the contiguous
        cache drops overflow writes)."""
        if self.model.cfg.sliding_window:
            return self._mb
        return min(P.blocks_for(prompt_len + gen_tokens - 1,
                                self.cfg.block_size), self._mb)

    def serve(self, requests, *, gen_tokens: int, seed: int | None = None,
              return_stats: bool = False):
        """Serve ``requests`` (1-D token arrays); each gets ``gen_tokens``
        generated tokens.  Returns outputs in request order (and a stats
        dict when ``return_stats``)."""
        cfg, model = self.cfg, self.model
        B, K = cfg.slots, cfg.k_steps
        requests = [jnp.asarray(r, jnp.int32).reshape(-1) for r in requests]
        stats = {"host_syncs": 0, "dispatches": 0, "prefill_calls": 0,
                 "decode_steps": 0, "tokens": 0}
        outputs: dict[int, list[int]] = {}
        if gen_tokens < 1 or not requests:
            return ([], stats) if return_stats else []

        if cfg.paged:
            cache = model.init_paged_cache(B, cfg.cache_len,
                                           block_size=cfg.block_size,
                                           num_blocks=self._num_blocks)
            for r in requests:
                need = self._blocks_needed(int(r.shape[0]), gen_tokens)
                if need > self._num_blocks:
                    raise ValueError(
                        f"request of {int(r.shape[0])} tokens needs {need} "
                        f"blocks but the pool has {self._num_blocks}")
        else:
            cache = model.init_cache(B, cfg.cache_len)
        stats["cache_bytes"] = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
        state = init_slot_state(B)
        if self.mesh is not None:
            cache = self._place_cache(cache)
        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        queue = deque(range(len(requests)))
        slot_rid = [-1] * B     # request id per slot (host mirror)
        slot_rem = [0] * B      # remaining budget     (host mirror)
        # host mirror of worst-case block reservations (paged): a slot
        # reserves its request's lifetime maximum at admission and drops it
        # when the request finishes — the device free-list only ever runs
        # *ahead* of this view (it reclaims blocks mid-scan), so admission
        # against reservations can never underflow the pool
        slot_rsv = [0] * B

        while queue or any(r >= 0 for r in slot_rid):
            free = [s for s in range(B) if slot_rid[s] < 0]
            if queue and free:
                if cfg.paged:
                    take_slots, rids = [], []
                    rsv_total = sum(slot_rsv)
                    for s in free:
                        if not queue:
                            break
                        need = self._blocks_needed(
                            int(requests[queue[0]].shape[0]), gen_tokens)
                        if rsv_total + need > self._num_blocks:
                            break   # FIFO: head request must fit first
                        rsv_total += need
                        slot_rsv[s] = need
                        take_slots.append(s)
                        rids.append(queue.popleft())
                    assert take_slots or any(r >= 0 for r in slot_rid), \
                        "admission stalled with an idle pool"
                else:
                    take = min(len(free), len(queue))
                    take_slots = free[:take]
                    rids = [queue.popleft() for _ in range(take)]
                if rids:
                    key, sub = jax.random.split(key)
                    cache, state, first, ncalls = self._admit(
                        cache, state, take_slots,
                        [requests[r] for r in rids], gen_tokens, sub)
                    stats["prefill_calls"] += ncalls
                    stats["host_syncs"] += ncalls
                    stats["tokens"] += len(rids)
                    for s, r, t in zip(take_slots, rids, first):
                        outputs[r] = [t]
                        slot_rid[s], slot_rem[s] = r, gen_tokens - 1
                    for s in take_slots:   # gen_tokens == 1 finishes now
                        if slot_rem[s] <= 0:
                            slot_rid[s] = -1
                            slot_rsv[s] = 0
            if not any(r >= 0 for r in slot_rid):
                continue

            key, sub = jax.random.split(key)
            state, cache, toks, emitted = self._dispatch(
                self.params, state, cache, sub)
            toks_h, em_h = jax.device_get((toks, emitted))
            stats["host_syncs"] += 1
            stats["dispatches"] += 1
            stats["decode_steps"] += K
            for s in range(B):
                r = slot_rid[s]
                if r < 0:
                    continue
                row = [int(t) for t in toks_h[s][em_h[s]]]
                outputs[r].extend(row)
                stats["tokens"] += len(row)
                slot_rem[s] -= len(row)
                if slot_rem[s] <= 0:
                    slot_rid[s] = -1
                    slot_rsv[s] = 0  # device freed the blocks mid-scan

        outs = [outputs[i] for i in sorted(outputs)]
        return (outs, stats) if return_stats else outs
