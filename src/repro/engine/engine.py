"""Device-resident serving engine: continuous batching with on-device
scheduling, batched multi-slot prefill, real sampling, opt-in sharding.

The host keeps only what it must (the request queue and a mirror of each
slot's budget, maintained from the dispatch results it already fetched —
no extra syncs); everything per-token lives on device:

* **decode** — one jitted dispatch runs ``k_steps`` decode steps under
  ``lax.scan`` (scheduler.make_decode_dispatch); the host syncs once per
  dispatch to drain the emitted-token grid.
* **prefill** — all free slots' pending prompts go through batched
  ``model.prefill`` calls (a single right-padded call when the model
  supports it, else one call per distinct prompt length) and their cache
  rows are scattered into the live cache in one jitted update.  When the
  whole pool is being (re)filled in one equal-length batch the returned
  cache simply *replaces* the live one — the scatter-free path.
* **sampling** — greedy / temperature / top-k via engine.sampler with a
  per-step threaded PRNG key (the old host loop's ``greedy=False`` was
  silently argmax).
* **sharding** — pass ``mesh=`` to place params with
  ``launch.sharding.params_shardings`` (quantized ``wq/data`` / ``wq/scale``
  leaves inherit the dense weight's layout by tree path) and the decode
  cache with ``cache_shardings``; all jitted steps then run GSPMD-partitioned.
* **paged KV cache** — ``paged=True`` swaps the per-slot contiguous cache
  for a global block pool with per-slot block tables and a device-resident
  free-list (engine/paged.py): memory tracks live tokens instead of
  ``slots * cache_len``, admission reserves each request's lifetime worst
  case against the pool (FIFO; requests wait when the head doesn't fit),
  and blocks recycle inside the K-step scan as slots drain.  Greedy
  outputs stay token-exact vs the contiguous cache.
* **chunked prefill** — ``chunk_size > 0`` (paged only) moves prompt
  prefill *into* the decode dispatch: admission just maps blocks and arms
  the slot's prompt buffer, and each scan step prefills one
  ``chunk_size``-token piece alongside the other slots' decode step
  (scheduler.py), so long prompts stream instead of stalling decode.
  Chunk pieces are bit-exact vs one-shot prefill (same flash tile math
  with offset masks, SSD state threaded on the ``ssm_chunk`` grid, same-
  dtype cache reads) with one carve-out: capacity-routed MoE is run
  **dropless** inside chunks — GShard's round-major queue positions are
  non-causal (a token's 2nd-choice position depends on later tokens' 1st
  choices), so one-shot *drop* decisions cannot be reproduced from a
  chunk's worth of tokens; outputs match exactly whenever the one-shot
  path doesn't overflow an expert queue.
* **prefix caching** — ``prefix_cache=True`` (implies chunked prefill)
  shares full prompt blocks across requests: a host-side chained-hash
  index (engine/prefix.py) maps matched leading blocks into the new slot's
  table with ``refcount += 1`` and only the unmatched tail is prefilled;
  released blocks stay cached (the index holds one reference) until LRU
  eviction makes room.  A partially-matched last block is mapped shared
  and copy-on-write protected: the first decode write pops a private copy.
  The reservation ledger counts only non-shared blocks, so a warm cache
  admits more concurrency from the same pool.  Sharing is content-sound
  for causal attention stacks without position-keyed ring caches or
  recurrent state; SWA / SSM / hybrid configs run with matching disabled
  (the chunked machinery still applies, outputs stay exact, nothing is
  shared).

* **self-speculative decoding** — ``n_spec > 0`` (paged only; pass a
  quantized ``draft_params`` tree) swaps each dispatch step for a
  speculative round: the quantized tree drafts up to ``n_spec`` tokens,
  one full-precision multi-token verify forward accepts a prefix (greedy
  match, or lossless rejection sampling for temperature/top-k/top-p), and
  rejected positions roll back per slot (engine/spec.py).  Speculation
  **composes** with chunked prefill and prefix caching: chunk pieces, CoW
  prefix writes and speculative rounds are orthogonal phases of one scan
  step sharing a spec-aware span allocation (a draft write into a shared
  prompt block CoWs exactly like a decode write), so shared-prefix
  workloads can measure draft fidelity too.  The speculation depth is
  dynamic by default (``spec_dynamic``): a host-side AIMD controller
  walks it 1..n_spec from the acceptance telemetry — the depth is a
  traced operand, so moves never recompile.  Greedy outputs stay
  token-exact vs the non-speculative engine for any draft and any depth
  trajectory; the draft acceptance rate (stats ``draft_accepted /
  draft_tokens``) doubles as a data-free behavioral-fidelity metric for
  the quantization method.

Right-padded prefill is only exact when a row's hidden states cannot depend
on positions after it or on other tokens' presence: pure causal attention
qualifies; SWA ring caches (slot = position % window would index pad
positions), Mamba state accumulation, and capacity-routed MoE (pad tokens
compete for per-expert capacity and can displace real tokens) do not —
those configs fall back to equal-length grouping automatically.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import paged as P
from repro.engine.prefix import PrefixIndex
from repro.engine.sampler import SamplingParams, sample
from repro.engine.scheduler import init_slot_state, make_decode_dispatch
from repro.models.lm import Model
from repro.telemetry.counters import (COUNTER_KEYS, bump, counter_totals,
                                      init_counters)

_BKEYS = P.BSTATE_KEYS


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 2          # size of the continuous-batching pool
    cache_len: int = 256    # decode cache capacity per slot
    k_steps: int = 8        # decode steps per dispatch (1 host sync each)
    sampling: SamplingParams = field(default_factory=SamplingParams)
    seed: int = 0
    paged: bool = False     # paged KV cache (block pool + block tables)
    block_size: int = 16    # tokens per KV block (paged only)
    num_blocks: int = 0     # pool size; 0 -> slots * ceil(cap / block_size)
                            # (capacity parity with the contiguous cache)
    chunk_size: int = 0     # >0: chunked prefill inside the decode dispatch
                            # (paged only; tokens per in-scan prefill piece)
    prefix_cache: bool = False  # refcounted prompt-block sharing (paged;
                                # implies chunked prefill)
    n_spec: int = 0         # >0: self-speculative decoding — draft up to
                            # n_spec tokens per round with the quantized
                            # ``draft_params`` tree, verify with one
                            # full-precision forward (paged only; pass
                            # draft_params= to Engine).  Composes with
                            # chunk_size and prefix_cache: speculation,
                            # chunked prefill and CoW prefix writes are
                            # orthogonal phases of one dispatch scan step
    spec_dynamic: bool = True   # move the speculation depth 1..n_spec at
                                # runtime from acceptance telemetry
                                # (spec.DepthController); depth is a traced
                                # operand, so moves never recompile.
                                # False pins depth = n_spec
    check_invariants: bool = False  # assert allocator conservation after
                                    # every admission/dispatch (tests; slow)


class Engine:
    """Continuous-batching serving engine over a built :class:`Model`."""

    def __init__(self, model: Model, params, cfg: EngineConfig | None = None,
                 *, mesh=None, draft_params=None, metrics=None, tracer=None,
                 **kw):
        if cfg is None:
            cfg = EngineConfig(**kw)
        elif kw:
            raise TypeError("pass either cfg= or keyword fields, not both")
        # host-side observability (repro.telemetry) — both optional, both
        # fed exclusively from values the serve loop already fetched, so
        # enabling them changes no jitted signature and adds no host sync
        self.metrics = metrics      # MetricsRegistry | None
        self.tracer = tracer        # Tracer | None
        if model.cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "Engine drives LM-style models; vlm/encdec need modality "
                "inputs (see examples/)")
        if cfg.prefix_cache and not cfg.chunk_size:
            cfg = EngineConfig(**{**cfg.__dict__,
                                  "chunk_size": 4 * cfg.block_size})
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        # right-padded prefill is exact only for pure-causal-attention
        # stacks with non-ring caches AND no cross-token coupling: MoE is
        # excluded because pad tokens join capacity-limited routing and can
        # displace real tokens' expert assignments (see module docstring)
        mcfg = model.cfg
        self._can_pad = (mcfg.family == "dense"
                         and not mcfg.sliding_window)
        self.params = self._place_params(params) if mesh is not None else params

        sp, K = cfg.sampling, cfg.k_steps
        if K < 1:
            raise ValueError(f"k_steps must be >= 1, got {K}")
        if (cfg.chunk_size or cfg.prefix_cache) and not cfg.paged:
            raise ValueError("chunk_size / prefix_cache need paged=True")
        if cfg.n_spec:
            if not cfg.paged:
                raise ValueError(
                    "speculative decoding (n_spec > 0) rides the paged "
                    "engine: pass paged=True")
            if cfg.n_spec >= K:
                raise ValueError(
                    f"n_spec must be < k_steps (got n_spec={cfg.n_spec}, "
                    f"k_steps={K}): the dispatch runs k_steps speculative "
                    f"rounds and sizes its token grid k_steps*(n_spec+1) — "
                    f"raise k_steps or lower n_spec")
            if draft_params is None:
                raise ValueError(
                    "speculative decoding needs draft_params: a quantized "
                    "copy of the serving weights, e.g. repro.quantize("
                    "params, base, qcfg, mode='storage')[0]")
            if mcfg.sliding_window and cfg.n_spec + 1 > mcfg.sliding_window:
                raise ValueError(
                    f"n_spec + 1 ({cfg.n_spec + 1}) must fit inside the "
                    f"sliding window ({mcfg.sliding_window}): a round's "
                    f"verify span may not wrap the whole ring")
            has_moe = (mcfg.family == "moe"
                       or (mcfg.family == "hybrid" and mcfg.moe_every))
            if has_moe and mcfg.capacity_factor * mcfg.top_k < mcfg.n_experts:
                raise ValueError(
                    f"speculative verify routes MoE dropless, but this "
                    f"config's decode path can drop tokens "
                    f"(capacity_factor {mcfg.capacity_factor} * top_k "
                    f"{mcfg.top_k} < n_experts {mcfg.n_experts}), so greedy "
                    f"speculative output could diverge from the "
                    f"non-speculative engine when an expert queue "
                    f"overflows.  Serve dropless (capacity_factor >= "
                    f"n_experts / top_k) to speculate — what a serving "
                    f"engine wants regardless")
        elif draft_params is not None:
            raise ValueError("draft_params without n_spec > 0 does nothing: "
                             "set n_spec to enable speculative decoding")
        if cfg.paged:
            window = mcfg.sliding_window
            cap = min(cfg.cache_len, window) if window else cfg.cache_len
            if window and cap != window:
                raise ValueError(
                    f"paged SWA serving needs cache_len >= sliding_window "
                    f"({cfg.cache_len} < {window})")
            if window and window % cfg.block_size:
                raise ValueError(
                    f"block_size {cfg.block_size} must divide the sliding "
                    f"window {window}: ring positions are block-mapped "
                    f"(pos % window straddles the block grid otherwise)")
            self._mb = P.blocks_for(cap, cfg.block_size)  # blocks per slot
            self._num_blocks = cfg.num_blocks or cfg.slots * self._mb
        if cfg.chunk_size:
            if (mcfg.family in ("ssm", "hybrid")
                    and cfg.chunk_size % mcfg.ssm_chunk):
                raise ValueError(
                    f"chunked prefill over SSM state is bit-exact only on "
                    f"the SSD chunk grid: chunk_size {cfg.chunk_size} must "
                    f"be a multiple of ssm_chunk {mcfg.ssm_chunk}")
            # prompt-block sharing is content-sound only when a block's KV
            # is a pure function of the token prefix: ring caches are
            # position-keyed (mod window) and SSM state is recurrent, so
            # those families run chunked but unshared
            self._can_match = (cfg.prefix_cache
                               and mcfg.family in ("dense", "moe")
                               and not mcfg.sliding_window)
            self._index = PrefixIndex(cfg.block_size)
            self._hold_blocks: set[int] = set()   # index + pending holds
            self._pcache = None                   # persistent cache/state
            self._pstate = None
        # Every jitted entry point goes through _register so the compile
        # contracts (repro.staticcheck) and the serve telemetry see one
        # authoritative registry: name -> jitted fn, donated argnums, and
        # where the cache tree sits in the signature / result.
        self._entries: dict[str, dict] = {}
        self._dispatch = self._register(
            "_dispatch",
            make_decode_dispatch(model, sp, K, paged=cfg.paged,
                                 cow=cfg.prefix_cache),
            donate=(1, 2), cache_arg=2, cache_out=1)
        if cfg.n_spec:
            self._draft_params = (self._place_params(draft_params)
                                  if mesh is not None else draft_params)
            self._dispatch_spec = self._register(
                "_dispatch_spec",
                make_decode_dispatch(model, sp, K, paged=True,
                                     cow=cfg.prefix_cache,
                                     n_spec=cfg.n_spec),
                donate=(2, 3), cache_arg=3, cache_out=1)
        if cfg.chunk_size:
            self._dispatch_chunk = self._register(
                "_dispatch_chunk",
                make_decode_dispatch(model, sp, K, paged=True,
                                     cow=cfg.prefix_cache,
                                     chunk=cfg.chunk_size),
                donate=(1, 2), cache_arg=2, cache_out=1)
            if cfg.n_spec:
                self._dispatch_spec_chunk = self._register(
                    "_dispatch_spec_chunk",
                    make_decode_dispatch(model, sp, K, paged=True,
                                         cow=cfg.prefix_cache,
                                         chunk=cfg.chunk_size,
                                         n_spec=cfg.n_spec),
                    donate=(2, 3), cache_arg=3, cache_out=1)
            self._admit_chunk = self._register(
                "_admit_chunk", self._admit_chunk_impl, donate=(0, 1),
                cache_arg=0, cache_out=0)
            self._evict = self._register(
                "_evict", self._evict_impl, donate=(0, 1),
                cache_arg=0, cache_out=0)
        self._scatter = self._register(
            "_scatter", self._scatter_impl, donate=(0, 1),
            cache_arg=0, cache_out=0)
        self._scatter_paged = self._register(
            "_scatter_paged", self._scatter_paged_impl, donate=(0, 1),
            cache_arg=0, cache_out=0)
        # paged prefill sizes the part cache to the admitted group (block-
        # aligned prompt rows), so admission cost tracks prompt length; the
        # contiguous path always materializes cache_len rows.
        # Donation is intentionally impossible here: the only large operand
        # is ``params``, which must survive every future dispatch/prefill
        # call, and the part cache is *produced*, not consumed — there is
        # no dead input buffer for the output to alias.
        self._prefill_full = self._register(
            "_prefill_full",
            lambda p, toks, cl: model.prefill(p, {"tokens": toks},
                                              cache_len=cl),
            static_argnums=(2,))
        self._prefill_padded = self._register(
            "_prefill_padded",
            lambda p, toks, lens, cl: model.prefill(p, {"tokens": toks},
                                                    cache_len=cl,
                                                    lengths=lens),
            static_argnums=(3,))

    # -- jitted entry-point registry ----------------------------------------

    def _register(self, name: str, fun, *, donate: tuple = (),
                  static_argnums: tuple = (), cache_arg: int | None = None,
                  cache_out: int | None = None):
        """Jit ``fun`` and record it as a named engine entry point.

        ``donate`` are the argnums handed to ``donate_argnums`` (the
        compile contracts assert each donated cache/pool buffer actually
        aliases an output); ``cache_arg``/``cache_out`` locate the cache
        tree in the signature and the result tuple so dtype-hygiene checks
        can compare leaf dtypes input -> output."""
        jitted = jax.jit(fun, donate_argnums=donate,
                         static_argnums=static_argnums)
        self._entries[name] = {
            "fn": jitted, "fun": fun, "donate": tuple(donate),
            "static_argnums": tuple(static_argnums),
            "cache_arg": cache_arg, "cache_out": cache_out,
        }
        return jitted

    def entry_points(self) -> dict[str, dict]:
        """The live jitted entry points of this engine configuration (a
        shallow copy: name -> registry record).  repro.staticcheck lowers
        every record across the config matrix and checks its compile
        contracts; the serve CLI reads compile counts off the same set."""
        return dict(self._entries)

    def compile_counts(self) -> dict[str, int]:
        """Traced-signature count per entry point (jit cache size).  A
        steady-state serve loop holds this at 1 per entry; growth across
        dispatches means an avoidable recompile (shape drift or weak-type
        literals in the argument tree)."""
        out = {}
        for name, e in self._entries.items():
            fn = e["fn"]
            try:
                out[name] = int(fn._cache_size())
            except Exception:  # jax without the AOT cache-size probe
                out[name] = -1
        return out

    # -- sharded placement --------------------------------------------------

    def _place_params(self, params):
        from repro.launch.sharding import params_shardings
        shard = params_shardings(jax.eval_shape(lambda: params),
                                 self.model.cfg, self.mesh)
        return jax.device_put(params, shard)

    def _place_cache(self, cache):
        from repro.launch.sharding import cache_shardings
        shard = cache_shardings(jax.eval_shape(lambda: cache),
                                self.model.cfg, self.mesh)
        return jax.device_put(cache, shard)

    # -- batched prefill + single-scatter admission -------------------------

    @staticmethod
    def _scatter_impl(cache, state, part_cache, slots, first, remaining0):
        """Scatter ``part_cache`` rows (batch axis 1 under the period axis)
        into the live cache at ``slots`` and arm the slot state — one jitted
        update for the whole admitted group."""
        def sc(full, part):
            return full.at[:, slots].set(part.astype(full.dtype))

        new = dict(cache)
        new["stack"] = jax.tree.map(sc, cache["stack"], part_cache["stack"])
        if "prefix" in cache:
            new["prefix"] = jax.tree.map(sc, cache["prefix"],
                                         part_cache["prefix"])
        new["lengths"] = cache["lengths"].at[slots].set(
            part_cache["lengths"])
        state = {
            **state,
            "cur": state["cur"].at[slots, 0].set(first),
            "active": state["active"].at[slots].set(remaining0 > 0),
            "remaining": state["remaining"].at[slots].set(remaining0),
        }
        return new, state

    @staticmethod
    def _scatter_paged_impl(cache, state, part_cache, slots, lens, first,
                            remaining0, counts):
        """Admit one prefilled group into the paged cache: release the
        target slots' stale blocks, allocate ``counts[i]`` fresh blocks per
        slot, scatter the part cache's K/V rows block-wise into the pools
        (rows past a slot's true need land in the trash block) and dense
        (SSM) leaves slot-wise — one jitted update for the whole group."""
        B = state["active"].shape[0]
        bstate = {k: cache[k] for k in _BKEYS}
        nf0 = bstate["n_free"]
        done = jnp.zeros((B,), bool).at[slots].set(True)
        bstate = P.release_slots(bstate, done)
        released = bstate["n_free"] - nf0

        # static block geometry from the part tree (absent for pure-SSM)
        nbl = 0
        for lcache in part_cache["stack"].values():
            if "k" in lcache:
                bs = next(l for l in cache["stack"].values()
                          if "pk" in l)["pk"].shape[2]
                nbl = lcache["k"].shape[2] // bs
                break
        popped = jnp.int32(0)
        if nbl:
            nf1 = bstate["n_free"]
            bstate, wids = P.alloc_admit(bstate, slots, counts, nbl)
            popped = nf1 - bstate["n_free"]
        # a slot that owes no decode steps must not write or grow; its
        # blocks are released again right below (the KV is never read —
        # the single output token came straight from the prefill logits)
        bstate["slot_active"] = bstate["slot_active"].at[slots].set(
            remaining0 > 0)
        nf2 = bstate["n_free"]
        bstate = P.release_slots(bstate, done & (remaining0 <= 0))
        released = released + (bstate["n_free"] - nf2)

        def scatter_group(pool_group, part_group):
            new_group = {}
            for lkey, lcache in pool_group.items():
                pl, nl = part_group[lkey], {}
                for name, leaf in lcache.items():
                    if name in ("pk", "pv"):
                        src = pl["k" if name == "pk" else "v"]
                        n, g, L = src.shape[:3]
                        blocks = src.reshape(n, g * nbl, L // nbl,
                                             *src.shape[3:])
                        nl[name] = leaf.at[:, wids.reshape(-1)].set(
                            blocks.astype(leaf.dtype))
                    else:  # contiguous per-slot leaf (SSM state)
                        nl[name] = leaf.at[:, slots].set(
                            pl[name].astype(leaf.dtype))
                new_group[lkey] = nl
            return new_group

        new = dict(cache)
        new.update(bstate)
        new["stack"] = scatter_group(cache["stack"], part_cache["stack"])
        if "prefix" in cache:
            new["prefix"] = scatter_group(cache["prefix"],
                                          part_cache["prefix"])
        new["lengths"] = cache["lengths"].at[slots].set(lens)
        state = {
            **state,
            "cur": state["cur"].at[slots, 0].set(first),
            "active": state["active"].at[slots].set(remaining0 > 0),
            "remaining": state["remaining"].at[slots].set(remaining0),
            "ctr": bump(state["ctr"], blocks_popped=popped,
                        blocks_released=released),
        }
        return new, state

    # -- chunked / prefix-cached admission ----------------------------------

    def _admit_chunk_impl(self, cache, state, slot, tokens, L, shared_ids,
                          n_shared, n_new, n_retained, pf_start,
                          shared_until, budget):
        """Admit one request into ``slot`` for in-scan chunked prefill: no
        model forward here — release the stale slot, map shared (prefix-hit)
        blocks + pop fresh ones, zero the slot's recurrent state, and arm
        the prompt buffer.  The first token is sampled inside the dispatch
        when the last chunk lands."""
        B = state["active"].shape[0]
        bstate = {k: cache[k] for k in _BKEYS}
        nf0 = bstate["n_free"]
        done = jnp.zeros((B,), bool).at[slot].set(True)
        bstate = P.release_slots(bstate, done)
        nf1 = bstate["n_free"]
        bstate, new_ids = P.admit_slot(bstate, slot, shared_ids, n_shared,
                                       n_new, n_retained, self._mb)

        def zero_group(group):
            return {lk: {name: (leaf if name in ("pk", "pv")
                                else leaf.at[:, slot].set(0))
                         for name, leaf in lv.items()}
                    for lk, lv in group.items()}

        new = dict(cache)
        new.update(bstate)
        new["stack"] = zero_group(cache["stack"])
        if "prefix" in cache:
            new["prefix"] = zero_group(cache["prefix"])
        new["lengths"] = cache["lengths"].at[slot].set(pf_start)
        state = {
            **state,
            "active": state["active"].at[slot].set(False),
            "remaining": state["remaining"].at[slot].set(0),
            "prompt": state["prompt"].at[slot].set(tokens),
            "pf_pos": state["pf_pos"].at[slot].set(pf_start),
            "pf_len": state["pf_len"].at[slot].set(L),
            "budget": state["budget"].at[slot].set(budget),
            "pf_shared": state["pf_shared"].at[slot].set(shared_until),
            # pf_start (not shared_until): tokens actually skipped, the
            # same quantity the host's stats["prefix_hits"] accumulates
            "ctr": bump(state["ctr"],
                        prefix_hit_tokens=pf_start,
                        blocks_released=nf1 - nf0,
                        blocks_popped=nf1 - bstate["n_free"]),
        }
        return new, state, new_ids

    @staticmethod
    def _evict_impl(cache, state, ids):
        """Drop host holds on ``ids`` and count the blocks that actually
        hit the free stack on the device counter tree."""
        nf0 = cache["n_free"]
        bstate = P.release_refs({k: cache[k] for k in _BKEYS}, ids)
        state = {**state,
                 "ctr": bump(state["ctr"],
                             blocks_released=bstate["n_free"] - nf0)}
        return {**cache, **bstate}, state

    # -- allocator invariants (check_invariants=True) -----------------------

    def _assert_invariants(self, cache, state=None) -> None:
        """Conservation of the block pool, checked on the device truth:
        free stack and referenced blocks partition the pool, and every
        block's refcount equals its live table references plus the host's
        index/pending hold.  With ``state`` the device counter tree is
        checked too: pops minus releases must account for every block out
        of the free stack since the counters were zeroed
        ("popped == released + live"), and every drafted position must be
        either accepted or rejected."""
        bs = jax.device_get({k: cache[k] for k in _BKEYS})
        NB = self._num_blocks
        n_free = int(bs["n_free"])
        free = [int(b) for b in bs["free"][:n_free]]
        assert len(set(free)) == n_free, "free stack holds duplicates"
        ref = np.asarray(bs["ref"])
        held = {b for b in range(NB) if ref[b] > 0}
        assert not (set(free) & held), "block both free and referenced"
        assert n_free + len(held) == NB, (
            f"pool leak: {n_free} free + {len(held)} held != {NB}")
        tbl = np.asarray(bs["tbl"])
        counts = np.zeros(NB, np.int64)
        for b in tbl[tbl >= 0].reshape(-1):
            counts[b] += 1
        holds = getattr(self, "_hold_blocks", set())
        for b in range(NB):
            expect = counts[b] + (1 if b in holds else 0)
            assert ref[b] == expect, (
                f"block {b}: ref {ref[b]} != tables {counts[b]} + "
                f"hold {int(b in holds)}")
        if state is not None:
            ctr = counter_totals(jax.device_get(state["ctr"]))
            live0 = getattr(self, "_ctr_live0", 0)
            popped, released = ctr["blocks_popped"], ctr["blocks_released"]
            assert live0 + popped - released == NB - n_free, (
                f"counter leak: base {live0} + popped {popped} - released "
                f"{released} != live {NB - n_free}")
            assert ctr["drafted"] == ctr["accepted"] + ctr["rejected"], (
                f"spec counter leak: drafted {ctr['drafted']} != accepted "
                f"{ctr['accepted']} + rejected {ctr['rejected']}")

    def _group_cache_len(self, Lmax: int) -> int:
        """Prefill cache rows for one admitted group.  Contiguous: always
        the full per-slot capacity.  Paged: SWA pages the whole ring (the
        ring cap must match the decode cap), dense pages just the block-
        aligned prompt rows — admission memory tracks the prompt."""
        cfg = self.cfg
        if not cfg.paged:
            return cfg.cache_len
        if self.model.cfg.sliding_window:
            return cfg.cache_len
        return min(P.blocks_for(Lmax, cfg.block_size), self._mb) \
            * cfg.block_size

    def _admit(self, cache, state, free_slots, prompts, gen_tokens, key):
        """Prefill ``prompts`` into ``free_slots``.  Returns (cache, state,
        first_tokens host list, n_prefill_calls)."""
        cfg = self.cfg
        B = cfg.slots
        lens = [int(p.shape[0]) for p in prompts]
        if len(set(lens)) == 1:
            groups = [list(range(len(prompts)))]
        elif self._can_pad:
            groups = [list(range(len(prompts)))]
        else:  # ring/SSM caches: exact per-length batches
            by_len: dict[int, list[int]] = {}
            for i, L in enumerate(lens):
                by_len.setdefault(L, []).append(i)
            groups = list(by_len.values())

        firsts: dict[int, int] = {}
        rem0 = jnp.int32(gen_tokens - 1)
        for g in groups:
            key, sub = jax.random.split(key)
            Lmax = max(lens[i] for i in g)
            cl = self._group_cache_len(Lmax)
            toks = jnp.stack([
                jnp.pad(prompts[i], (0, Lmax - lens[i])) for i in g
            ]).astype(jnp.int32)
            if all(lens[i] == Lmax for i in g):
                logits, part = self._prefill_full(self.params, toks, cl)
            else:
                glens = jnp.asarray([lens[i] for i in g], jnp.int32)
                logits, part = self._prefill_padded(self.params, toks,
                                                    glens, cl)
            first = sample(logits, sub, self.cfg.sampling)
            g_slots = [free_slots[i] for i in g]
            if cfg.paged:
                if self.model.cfg.sliding_window:
                    counts = jnp.full((len(g),), self._mb, jnp.int32)
                else:
                    # clamp to per-slot capacity: an over-long prompt only
                    # keeps its first cap rows (the contiguous cache drops
                    # the overflow the same way) — without the clamp the
                    # allocator would debit blocks the scatter never places
                    counts = jnp.asarray(
                        [min(P.blocks_for(lens[i], cfg.block_size),
                             self._mb) for i in g], jnp.int32)
                cache, state = self._scatter_paged(
                    cache, state, part, jnp.asarray(g_slots, jnp.int32),
                    jnp.asarray([lens[i] for i in g], jnp.int32),
                    first, rem0, counts)
            elif len(g) == B and g_slots == list(range(B)):
                # scatter-free: the prefill result IS the new cache
                if self.mesh is not None:
                    part = self._place_cache(part)
                cache = part
                state = {**state,
                         "cur": first[:, None].astype(jnp.int32),
                         "active": jnp.broadcast_to(rem0 > 0, (B,)),
                         "remaining": jnp.broadcast_to(rem0, (B,))}
            else:
                cache, state = self._scatter(
                    cache, state, part, jnp.asarray(g_slots, jnp.int32),
                    first, rem0)
            for i, t in zip(g, jax.device_get(first)):
                firsts[i] = int(t)
        return cache, state, [firsts[i] for i in range(len(prompts))], \
            len(groups)

    # -- serve --------------------------------------------------------------

    def _spec_controller(self):
        """Fresh dynamic-depth policy for one serve() call (spec.py).  With
        ``spec_dynamic=False`` the thresholds are pushed out of [0, 1], so
        no acceptance rate ever moves the depth off ``n_spec`` — one code
        path either way."""
        from repro.engine.spec import DepthController
        if self.cfg.spec_dynamic:
            return DepthController(self.cfg.n_spec)
        return DepthController(self.cfg.n_spec, lo=-1.0, hi=2.0)

    def _blocks_needed(self, prompt_len: int, gen_tokens: int) -> int:
        """Worst-case pool blocks one request can ever hold: SWA rings page
        the whole window; dense requests write ``prompt + gen - 1`` cache
        rows over their lifetime (capacity-clamped, like the contiguous
        cache drops overflow writes).  Speculative rounds overshoot by up
        to ``n_spec`` rows past the budget before rolling back (the last
        round's span), so the reservation covers that transient too."""
        if self.model.cfg.sliding_window:
            return self._mb
        return min(P.blocks_for(prompt_len + gen_tokens - 1 + self.cfg.n_spec,
                                self.cfg.block_size), self._mb)

    # -- telemetry (repro.telemetry) ----------------------------------------

    def _request_done(self, prompt_len, gen_len, t_enq, t_admit, t_first,
                      prefix_hit_frac=None):
        """Record one finished request's lifecycle histograms (no-op
        without a registry; all inputs are host floats already in hand)."""
        m = self.metrics
        if m is None:
            return
        now = time.perf_counter()
        m.counter("requests.completed").inc()
        m.histogram("request.ttft_s", unit="s").observe(t_first - t_enq)
        m.histogram("request.queue_wait_s", unit="s").observe(
            t_admit - t_enq)
        m.histogram("request.tpot_s", unit="s").observe(
            (now - t_first) / max(gen_len - 1, 1))
        m.histogram("request.prompt_len", lo=1.0, hi=1e6,
                    unit="tokens").observe(prompt_len)
        m.histogram("request.gen_len", lo=1.0, hi=1e6,
                    unit="tokens").observe(gen_len)
        if prefix_hit_frac is not None:
            m.histogram("request.prefix_hit_frac", lo=1e-3,
                        hi=1.0).observe(prefix_hit_frac)

    def _trace_dispatch(self, t0_us, totals, depth=None, drafted=0,
                        accepted=0):
        """One dispatch's trace events: a duration on the dispatch/spec
        track plus counter-track samples from the device counter tree."""
        tr = self.tracer
        if tr is None:
            return
        if depth is None:
            tr.complete("dispatch", "decode", t0_us,
                        {"k_steps": self.cfg.k_steps})
        else:
            tr.complete("spec", "rounds", t0_us,
                        {"k_steps": self.cfg.k_steps, "depth": depth,
                         "drafted": drafted, "accepted": accepted})
        tr.counter("tokens", {"emitted": totals["tokens"]})
        if self.cfg.paged:
            live = (getattr(self, "_ctr_live0", 0)
                    + totals["blocks_popped"] - totals["blocks_released"])
            tr.counter("blocks", {"live": live,
                                  "cow": totals["cow_copies"]})

    def _finalize_serve(self, stats, ctr_host):
        """End-of-serve telemetry: expose the device counters through
        ``stats["counters"]`` and fold them plus the allocator / spec
        gauges into the registry.  ``ctr_host`` is the last counter tree
        the dispatch sync fetched (None when no dispatch ran — e.g.
        ``gen_tokens == 1`` on the non-chunked path, where every token
        comes from prefill; the counters then read zero rather than
        costing a dedicated sync)."""
        totals = (counter_totals(ctr_host) if ctr_host is not None
                  else dict.fromkeys(COUNTER_KEYS, 0))
        stats["counters"] = totals
        m = self.metrics
        if m is None:
            return
        for k, v in totals.items():
            m.counter(f"device.{k}").inc(v)
        if self.cfg.n_spec:
            m.gauge("spec.depth").set(stats["spec_depth"])
            acc = m.gauge("spec.acceptance_rate")   # None -> "n/a"
            if totals["drafted"]:
                acc.set(totals["accepted"] / totals["drafted"])
        if self.cfg.paged:
            live = (getattr(self, "_ctr_live0", 0)
                    + totals["blocks_popped"] - totals["blocks_released"])
            m.gauge("alloc.live_blocks").set(live)
            m.gauge("alloc.free_blocks").set(self._num_blocks - live)
        if self.cfg.chunk_size:
            holds = len(self._hold_blocks)
            m.gauge("alloc.index_holds").set(holds)
            m.gauge("alloc.ledger_headroom").set(self._num_blocks - holds)
            m.counter("prefix.evictions").inc(
                stats.get("prefix_evictions", 0))

    def serve(self, requests, *, gen_tokens: int, seed: int | None = None,
              return_stats: bool = False):
        """Serve ``requests`` (1-D token arrays); each gets ``gen_tokens``
        generated tokens.  Returns outputs in request order (and a stats
        dict when ``return_stats``)."""
        cfg, model = self.cfg, self.model
        B, K = cfg.slots, cfg.k_steps
        requests = [jnp.asarray(r, jnp.int32).reshape(-1) for r in requests]
        stats = {"host_syncs": 0, "dispatches": 0, "prefill_calls": 0,
                 "decode_steps": 0, "tokens": 0, "prefill_tokens": 0,
                 "counters": dict.fromkeys(COUNTER_KEYS, 0)}
        spec_ctl = self._spec_controller() if cfg.n_spec else None
        if cfg.n_spec:
            stats.update(spec_rounds=0, draft_tokens=0, draft_accepted=0,
                         spec_depth=spec_ctl.depth)
        if gen_tokens < 1 or not requests:
            return ([], stats) if return_stats else []
        if cfg.chunk_size:
            return self._serve_chunked(requests, gen_tokens, seed,
                                       return_stats, stats, spec_ctl)
        outputs: dict[int, list[int]] = {}
        tr = self.tracer
        t_enq = time.perf_counter()     # all requests enqueue at serve()
        req_admit: dict[int, float] = {}
        ctr_host = None                 # last fetched device counter tree
        ctr_prev = dict.fromkeys(COUNTER_KEYS, 0)
        self._ctr_live0 = 0             # fresh cache: no live blocks yet

        if cfg.paged:
            cache = model.init_paged_cache(B, cfg.cache_len,
                                           block_size=cfg.block_size,
                                           num_blocks=self._num_blocks)
            for r in requests:
                need = self._blocks_needed(int(r.shape[0]), gen_tokens)
                if need > self._num_blocks:
                    raise ValueError(
                        f"request of {int(r.shape[0])} tokens needs {need} "
                        f"blocks but the pool has {self._num_blocks}")
        else:
            cache = model.init_cache(B, cfg.cache_len)
        stats["cache_bytes"] = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
        state = init_slot_state(B)
        if self.mesh is not None:
            cache = self._place_cache(cache)
        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        queue = deque(range(len(requests)))
        slot_rid = [-1] * B     # request id per slot (host mirror)
        slot_rem = [0] * B      # remaining budget     (host mirror)
        # host mirror of worst-case block reservations (paged): a slot
        # reserves its request's lifetime maximum at admission and drops it
        # when the request finishes — the device free-list only ever runs
        # *ahead* of this view (it reclaims blocks mid-scan), so admission
        # against reservations can never underflow the pool
        slot_rsv = [0] * B

        while queue or any(r >= 0 for r in slot_rid):
            free = [s for s in range(B) if slot_rid[s] < 0]
            if queue and free:
                if cfg.paged:
                    take_slots, rids = [], []
                    rsv_total = sum(slot_rsv)
                    for s in free:
                        if not queue:
                            break
                        need = self._blocks_needed(
                            int(requests[queue[0]].shape[0]), gen_tokens)
                        if rsv_total + need > self._num_blocks:
                            break   # FIFO: head request must fit first
                        rsv_total += need
                        slot_rsv[s] = need
                        take_slots.append(s)
                        rids.append(queue.popleft())
                    assert take_slots or any(r >= 0 for r in slot_rid), \
                        "admission stalled with an idle pool"
                else:
                    take = min(len(free), len(queue))
                    take_slots = free[:take]
                    rids = [queue.popleft() for _ in range(take)]
                if rids:
                    key, sub = jax.random.split(key)
                    t0_us = tr.now_us() if tr else 0.0
                    cache, state, first, ncalls = self._admit(
                        cache, state, take_slots,
                        [requests[r] for r in rids], gen_tokens, sub)
                    ta = time.perf_counter()
                    if tr:
                        tr.complete("admission", f"admit x{len(rids)}",
                                    t0_us, {"requests": len(rids),
                                            "prefill_calls": ncalls})
                    stats["prefill_calls"] += ncalls
                    stats["host_syncs"] += ncalls
                    stats["tokens"] += len(rids)
                    stats["prefill_tokens"] += sum(
                        int(requests[r].shape[0]) for r in rids)
                    for s, r, t in zip(take_slots, rids, first):
                        outputs[r] = [t]
                        slot_rid[s], slot_rem[s] = r, gen_tokens - 1
                        # first token comes from the prefill logits, so
                        # admission time IS first-token time here
                        req_admit[r] = ta
                    for s in take_slots:   # gen_tokens == 1 finishes now
                        if slot_rem[s] <= 0:
                            r = slot_rid[s]
                            self._request_done(
                                int(requests[r].shape[0]), gen_tokens,
                                t_enq, req_admit[r], req_admit[r])
                            slot_rid[s] = -1
                            slot_rsv[s] = 0
            if not any(r >= 0 for r in slot_rid):
                continue

            key, sub = jax.random.split(key)
            t0_us = tr.now_us() if tr else 0.0
            if cfg.n_spec:
                state, cache, toks, emitted = self._dispatch_spec(
                    self.params, self._draft_params, state, cache,
                    jnp.int32(spec_ctl.depth), sub)
            else:
                state, cache, toks, emitted = self._dispatch(
                    self.params, state, cache, sub)
            # the counter tree rides the returned state: same sync, no cost
            toks_h, em_h, ctr_host = jax.device_get(
                (toks, emitted, state["ctr"]))
            totals = counter_totals(ctr_host)
            if cfg.n_spec:
                d_dr = totals["drafted"] - ctr_prev["drafted"]
                d_ac = totals["accepted"] - ctr_prev["accepted"]
                stats["draft_tokens"] += d_dr
                stats["draft_accepted"] += d_ac
                stats["spec_rounds"] += K
                depth_used = spec_ctl.depth
                stats["spec_depth"] = spec_ctl.update(d_dr, d_ac)
                self._trace_dispatch(t0_us, totals, depth=depth_used,
                                     drafted=d_dr, accepted=d_ac)
            else:
                self._trace_dispatch(t0_us, totals)
            ctr_prev = totals
            stats["host_syncs"] += 1
            stats["dispatches"] += 1
            stats["decode_steps"] += K
            if cfg.paged and cfg.check_invariants:
                self._assert_invariants(cache, state)
            for s in range(B):
                r = slot_rid[s]
                if r < 0:
                    continue
                row = [int(t) for t in toks_h[s][em_h[s]]]
                outputs[r].extend(row)
                stats["tokens"] += len(row)
                slot_rem[s] -= len(row)
                if slot_rem[s] <= 0:
                    slot_rid[s] = -1
                    slot_rsv[s] = 0  # device freed the blocks mid-scan
                    self._request_done(int(requests[r].shape[0]),
                                       gen_tokens, t_enq, req_admit[r],
                                       req_admit[r])

        self._finalize_serve(stats, ctr_host)
        outs = [outputs[i] for i in sorted(outputs)]
        return (outs, stats) if return_stats else outs

    # -- chunked / prefix-cached serve loop ---------------------------------

    def _serve_chunked(self, requests, gen_tokens, seed, return_stats,
                       stats, spec_ctl=None):
        cfg, model = self.cfg, self.model
        B, K, C = cfg.slots, cfg.k_steps, cfg.chunk_size
        bs = cfg.block_size
        pcap = cfg.cache_len
        cap_rows = self._mb * bs if not model.cfg.sliding_window \
            else model.cfg.sliding_window
        persist = cfg.prefix_cache
        for r in requests:
            L = int(r.shape[0])
            if L > pcap:
                raise ValueError(
                    f"chunked prefill streams prompts through the paged "
                    f"cache: prompt of {L} tokens exceeds cache_len {pcap}")
            need = self._blocks_needed(L, gen_tokens)
            if need > self._num_blocks:
                raise ValueError(
                    f"request of {L} tokens needs {need} blocks but the "
                    f"pool has {self._num_blocks}")

        if persist and self._pcache is not None:
            cache, state = self._pcache, self._pstate
            self._pcache = self._pstate = None  # buffers are donated below
        else:
            cache = model.init_paged_cache(B, cfg.cache_len,
                                           block_size=bs,
                                           num_blocks=self._num_blocks)
            state = init_slot_state(B, prompt_cap=pcap)
            if self.mesh is not None:
                cache = self._place_cache(cache)
        stats["cache_bytes"] = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
        stats["prefix_hits"] = 0
        stats["prefix_evictions"] = 0
        # zero the device counters for this serve() (host-side tree
        # rebuild — covers a reused persistent state).  Index-held blocks
        # survive across serves, so conservation baselines on them.
        state = {**state, "ctr": init_counters()}
        self._ctr_live0 = len(self._hold_blocks)
        tr = self.tracer
        t_enq = time.perf_counter()
        ctr_host = None
        ctr_prev = dict.fromkeys(COUNTER_KEYS, 0)
        req_admit: dict[int, float] = {}
        req_first: dict[int, float] = {}
        req_pf: dict[int, float] = {}    # prefix-hit fraction per request
        slot_t0us = [0.0] * B            # admission trace clock per slot

        key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
        queue = deque(range(len(requests)))
        outputs: dict[int, list[int]] = {}
        slot_rid = [-1] * B
        slot_rem = [0] * B
        slot_rsv = [0] * B       # slot-private worst-case blocks
        slot_pf = [0] * B        # prompt tokens left to prefill (mirror)
        slot_keys = [[] for _ in range(B)]   # pinned index keys per slot
        slot_pend = [None] * B   # (tokens, first_block, ids) to register

        def drop_holds(ids):
            """Release host holds on ``ids`` (eviction / duplicate unwind);
            padded to the pool size so the jitted release compiles once."""
            nonlocal cache, state
            arr = np.full((self._num_blocks,), -1, np.int32)
            arr[:len(ids)] = ids
            cache, state = self._evict(cache, state, jnp.asarray(arr))
            self._hold_blocks.difference_update(ids)

        def try_evict(want: int) -> int:
            ids = self._index.evict(want) if self._can_match else []
            if ids:
                drop_holds(ids)
                stats["prefix_evictions"] += len(ids)
                if tr:
                    tr.instant("eviction", "evict", {"blocks": len(ids)})
            return len(ids)

        while queue or any(r >= 0 for r in slot_rid):
            free = [s for s in range(B) if slot_rid[s] < 0]
            while queue and free:
                rid = queue[0]
                prompt = requests[rid]
                L = int(prompt.shape[0])
                toks_np = np.asarray(prompt)
                full, part_len = L // bs, L % bs
                # A request's own prefix hits are pinned while it runs, so
                # they can crowd a tight pool out of reach (e.g. a warm
                # partial hit needing its CoW spare with every block cached
                # and self-pinned).  With running slots we FIFO-wait; with
                # an IDLE pool there is nothing to wait for, so each retry
                # unpins and force-evicts (own matches included) and
                # re-matches against the shrunken index — admission decays
                # toward a cold prefill, which the pool-size validation
                # guarantees fits.
                fits = False
                for _ in range(len(self._index) + 2):
                    matched_ids: list[int] = []
                    partial_id = None
                    keys: list = []
                    if self._can_match:  # excludes SWA/SSM/hybrid
                        matched_ids, partial_id, keys = self._index.match(
                            toks_np)
                        self._index.pin(keys)
                    matched_full = len(matched_ids)
                    partial_hit = partial_id is not None
                    matched_tokens = L if partial_hit else matched_full * bs
                    pf_start = min(matched_tokens, L - 1)
                    if model.cfg.sliding_window:
                        n_shared, n_new, n_ret = 0, self._mb, 0
                        shared = []
                        slot_need, hold_need = self._mb, 0
                    else:
                        new_full = full - matched_full
                        tail_new = 1 if (part_len and not partial_hit) \
                            else 0
                        n_new = new_full + tail_new
                        n_shared = matched_full + (1 if partial_hit else 0)
                        shared = matched_ids + ([partial_id] if partial_hit
                                                else [])
                        n_ret = new_full if self._can_match else 0
                        # speculative rounds overshoot the budget by up to
                        # n_spec rows before rolling back (the last
                        # round's span), so the lifetime worst case covers
                        # that transient too — mirrors _blocks_needed
                        lifetime = min(
                            P.blocks_for(min(L + gen_tokens - 1
                                             + cfg.n_spec, cap_rows),
                                         bs),
                            self._mb)
                        decode_alloc = lifetime - P.blocks_for(L, bs)
                        cow_extra = 1 if (partial_hit and gen_tokens > 1) \
                            else 0
                        slot_need = (n_new - n_ret) + decode_alloc \
                            + cow_extra
                        hold_need = n_ret
                    demand = (sum(slot_rsv) + len(self._hold_blocks)
                              + slot_need + hold_need - self._num_blocks)
                    if demand <= 0 or try_evict(demand) >= demand:
                        fits = True
                        break
                    self._index.unpin(keys)
                    if any(r >= 0 for r in slot_rid):
                        break   # FIFO: running slots will drain/unpin
                    if try_evict(demand) == 0:
                        break   # nothing cached left to reclaim
                if not fits:
                    break
                s = free.pop(0)
                queue.popleft()
                t0_us = tr.now_us() if tr else 0.0
                shared_arr = np.full((self._mb,), -1, np.int32)
                shared_arr[:len(shared)] = shared
                cache, state, new_ids = self._admit_chunk(
                    cache, state, jnp.int32(s),
                    jnp.asarray(np.pad(toks_np, (0, pcap - L)), jnp.int32),
                    jnp.int32(L), jnp.asarray(shared_arr),
                    jnp.int32(n_shared), jnp.int32(n_new),
                    jnp.int32(n_ret), jnp.int32(pf_start),
                    jnp.int32(matched_tokens), jnp.int32(gen_tokens - 1))
                slot_rid[s], slot_rem[s] = rid, gen_tokens
                slot_rsv[s] = slot_need
                slot_pf[s] = L - pf_start
                slot_keys[s] = keys
                outputs[rid] = []
                req_admit[rid] = time.perf_counter()
                req_pf[rid] = pf_start / L if L else 0.0
                if tr:
                    tr.complete("admission", f"req{rid}", t0_us,
                                {"prompt_len": L, "prefix_hit": pf_start,
                                 "shared_blocks": n_shared})
                    slot_t0us[s] = tr.now_us()
                stats["prefill_tokens"] += L - pf_start
                stats["prefix_hits"] += pf_start   # tokens NOT recomputed
                stats["prefill_calls"] += 1
                if n_ret:
                    ids = [int(i) for i in
                           jax.device_get(new_ids)[:n_ret]]
                    stats["host_syncs"] += 1
                    self._hold_blocks.update(ids)
                    slot_pend[s] = (toks_np, matched_full, ids)
                if cfg.check_invariants:
                    self._assert_invariants(cache, state)
            if not any(r >= 0 for r in slot_rid):
                assert not queue, "admission stalled with an idle pool"
                continue

            key, sub = jax.random.split(key)
            prefilling = any(p > 0 for p in slot_pf)
            t0_us = tr.now_us() if tr else 0.0
            if cfg.n_spec:
                dispatch = (self._dispatch_spec_chunk if prefilling
                            else self._dispatch_spec)
                state, cache, toks, emitted = dispatch(
                    self.params, self._draft_params, state, cache,
                    jnp.int32(spec_ctl.depth), sub)
            else:
                dispatch = (self._dispatch_chunk if prefilling
                            else self._dispatch)
                state, cache, toks, emitted = dispatch(
                    self.params, state, cache, sub)
            # the counter tree rides the returned state: same sync, no cost
            toks_h, em_h, ctr_host = jax.device_get(
                (toks, emitted, state["ctr"]))
            totals = counter_totals(ctr_host)
            if cfg.n_spec:
                d_dr = totals["drafted"] - ctr_prev["drafted"]
                d_ac = totals["accepted"] - ctr_prev["accepted"]
                stats["draft_tokens"] += d_dr
                stats["draft_accepted"] += d_ac
                stats["spec_rounds"] += K
                depth_used = spec_ctl.depth
                stats["spec_depth"] = spec_ctl.update(d_dr, d_ac)
                self._trace_dispatch(t0_us, totals, depth=depth_used,
                                     drafted=d_dr, accepted=d_ac)
            else:
                self._trace_dispatch(t0_us, totals)
            ctr_prev = totals
            stats["host_syncs"] += 1
            stats["dispatches"] += 1
            stats["decode_steps"] += K
            for s in range(B):
                if slot_rid[s] < 0 or slot_pf[s] <= 0:
                    continue
                slot_pf[s] = max(0, slot_pf[s] - K * C)
                if slot_pf[s] > 0:
                    continue
                if tr:
                    tr.complete("prefill-chunk", f"req{slot_rid[s]}",
                                slot_t0us[s])
                if slot_pend[s] is not None:
                    # the slot's new full prompt blocks now hold real KV:
                    # publish them to the prefix index (duplicates lose
                    # their pre-retained hold and die with the slot)
                    toks_np, first_block, ids = slot_pend[s]
                    slot_pend[s] = None
                    dups = self._index.register(toks_np, ids, first_block)
                    if dups:
                        drop_holds(dups)
                        slot_rsv[s] += len(dups)
                    dup_set = set(dups)
                    nkeys = self._index.keys_for(toks_np,
                                                 first_block + len(ids))
                    reg_keys = [k for k, bid in
                                zip(nkeys[first_block:], ids)
                                if bid not in dup_set]
                    self._index.pin(reg_keys)
                    slot_keys[s] = slot_keys[s] + reg_keys
            if cfg.check_invariants:
                self._assert_invariants(cache, state)
            for s in range(B):
                r = slot_rid[s]
                if r < 0:
                    continue
                row = [int(t) for t in toks_h[s][em_h[s]]]
                if row and r not in req_first:
                    req_first[r] = time.perf_counter()
                outputs[r].extend(row)
                stats["tokens"] += len(row)
                slot_rem[s] -= len(row)
                if slot_rem[s] <= 0:
                    assert slot_pend[s] is None, \
                        "slot finished before its prompt finished prefilling"
                    slot_rid[s] = -1
                    slot_rsv[s] = 0
                    self._index.unpin(slot_keys[s])
                    slot_keys[s] = []
                    self._request_done(
                        int(requests[r].shape[0]), gen_tokens, t_enq,
                        req_admit[r], req_first.get(r, req_admit[r]),
                        prefix_hit_frac=req_pf.get(r))

        self._finalize_serve(stats, ctr_host)
        if persist:
            self._pcache, self._pstate = cache, state
        outs = [outputs[i] for i in sorted(outputs)]
        return (outs, stats) if return_stats else outs
