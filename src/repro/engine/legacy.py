"""The pre-engine host-driven serving loop, kept as a benchmark baseline.

This is the loop ``launch/serve.py`` used to run: batch-1 prefill with a
Python-side cache scatter per slot, and a blocking ``int()`` host sync per
slot per decoded token.  ``benchmarks/bench_serve.py`` races it against the
device-resident engine, and the engine's greedy-parity tests pin
token-exactness against it.

The one behavioral change from the historical code: the ``greedy=False``
branch used to compute ``int(logits.argmax())`` — identical to the greedy
branch — so non-greedy serving was never real.  Both paths now route
through :mod:`repro.engine.sampler`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.sampler import SamplingParams, sample
from repro.engine.scheduler import make_decode_step
from repro.models.lm import Model


def single_slot_prefill(model: Model, params, cache, tokens_row, slot: int,
                        cache_len: int):
    """Prefill one request into ``slot`` of a live batch cache.

    Runs a batch-1 prefill and scatters the resulting per-layer cache rows
    into the slot (the per-slot path of host-driven continuous batching)."""
    logits, one_cache = model.prefill(
        params, {"tokens": tokens_row[None]}, cache_len=cache_len)

    # scatter every [n_periods, 1, ...] leaf into [n_periods, B, ...] slot
    def scatter(full_leaf, one_leaf):
        return full_leaf.at[:, slot].set(one_leaf[:, 0].astype(full_leaf.dtype))

    new_stack = jax.tree.map(scatter, cache["stack"], one_cache["stack"])
    new_cache = dict(cache)
    new_cache["stack"] = new_stack
    if "prefix" in cache:
        new_cache["prefix"] = jax.tree.map(scatter, cache["prefix"],
                                           one_cache["prefix"])
    new_cache["lengths"] = cache["lengths"].at[slot].set(
        one_cache["lengths"][0])
    return logits[0], new_cache


def serve_host_loop(model: Model, params, requests: list[jnp.ndarray], *,
                    batch: int, gen_tokens: int, cache_len: int,
                    sampling: SamplingParams | None = None, seed: int = 0,
                    return_stats: bool = False):
    """Serve ``requests`` with the old B-slot host-scheduled batcher."""
    sp = sampling or SamplingParams()
    step = jax.jit(make_decode_step(model, sp), donate_argnums=2)
    cache = model.init_cache(batch, cache_len)
    cur = jnp.zeros((batch, 1), jnp.int32)
    active = [-1] * batch                 # request id per slot
    remaining = [0] * batch
    outputs: dict[int, list[int]] = {}
    queue = list(range(len(requests)))
    key = jax.random.PRNGKey(seed)
    stats = {"host_syncs": 0, "dispatches": 0, "prefill_calls": 0,
             "decode_steps": 0, "tokens": 0}

    def fill_slot(slot, cache, cur, key):
        rid = queue.pop(0)
        logits, cache = single_slot_prefill(model, params, cache,
                                            requests[rid], slot, cache_len)
        key, sub = jax.random.split(key)
        nxt = int(sample(logits[None], sub, sp)[0])
        stats["prefill_calls"] += 1
        stats["host_syncs"] += 1
        stats["tokens"] += 1
        cur = cur.at[slot, 0].set(nxt)
        outputs[rid] = [nxt]
        active[slot] = rid
        remaining[slot] = gen_tokens - 1
        return cache, cur, key

    for slot in range(batch):
        if queue:
            cache, cur, key = fill_slot(slot, cache, cur, key)

    while any(a >= 0 for a in active):
        key, sub = jax.random.split(key)
        cur, logits, cache = step(params, cur, cache, sub)
        stats["dispatches"] += 1
        stats["decode_steps"] += 1
        for slot in range(batch):
            rid = active[slot]
            if rid < 0:
                continue
            outputs[rid].append(int(cur[slot, 0]))   # 1 sync per slot-token
            stats["host_syncs"] += 1
            stats["tokens"] += 1
            remaining[slot] -= 1
            if remaining[slot] <= 0:
                active[slot] = -1
                if queue:
                    cache, cur, key = fill_slot(slot, cache, cur, key)
    outs = [outputs[i] for i in sorted(outputs)]
    return (outs, stats) if return_stats else outs
