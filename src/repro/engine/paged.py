"""Paged KV-cache: global block pool, per-slot block tables, device free-list,
and **refcounted block sharing** (prefix caching / copy-on-write).

The contiguous engine cache reserves ``cache_len`` rows per slot per layer,
so the longest admissible request dictates the memory of every slot.  The
paged cache replaces the per-slot rows with a **global pool of fixed-size
token blocks** shared by all slots:

* per attention layer: ``pk`` / ``pv`` pools of shape
  ``[num_blocks + 1, block_size, Kv, hd]`` — the extra last block is a
  **trash sink**: writes for inactive slots (the dispatch keeps decoding
  finished slots, same as the contiguous engine) and capacity overflows are
  routed there instead of corrupting live blocks;
* one **block table** ``tbl [slots, max_blocks]`` shared by every attention
  layer (all layers advance in lockstep, so one table serves the stack);
  ``-1`` marks an unallocated entry and — because jnp gathers wrap negative
  indices — conveniently gathers the trash block, whose garbage the length
  mask then discards;
* a **device-resident free-list** ``free [num_blocks]`` (a stack of block
  ids) with scalar stack pointer ``n_free``: blocks are popped inside the
  jitted decode step the moment a slot's length crosses a block boundary and
  pushed back inside the K-step scan the moment a slot's budget drains — so
  capacity recycles mid-dispatch, without a host round-trip;
* a **refcount array** ``ref [num_blocks]``: a block's count of owners —
  one per block-table entry referencing it, plus one while the host prefix
  index holds it as a cached prompt block.  Allocation sets ``ref = 1``
  (``2`` when the block is simultaneously retained for the prefix index),
  prefix-hit admission maps an existing block with ``ref += 1``, and every
  release path *decrements*; a block returns to the free stack only when
  its refcount reaches zero.  Conservation invariant (pinned in
  tests/test_engine_prefix.py)::

      n_free + |{b : ref[b] > 0}| == num_blocks

  and ``ref[b]`` equals the number of live table entries pointing at ``b``
  plus the host's index/pending hold (0 or 1).

**Copy-on-write**: a slot may only append KV rows to a block it owns
exclusively.  When a decode write lands in a block with ``ref > 1`` (a
partially-filled prompt block shared through the prefix cache),
``alloc_step`` pops a fresh block, rewires the slot's table entry to it,
decrements the shared block, and reports the old block as ``cow_src`` so
the per-layer write copies its rows before appending.  Prefill-chunk writes
never CoW: a recomputed row whose target is shared is simply dropped (the
cached row already holds the identical value).

Everything here is shape-static jit-safe jnp; per-layer wiring lives in
``models/lm.py`` (``init_paged_cache`` / ``decode_step_paged`` /
``prefill_chunk_paged``), the host-side admission policy in ``engine.py``
and the host hash->block prefix index in ``prefix.py``.

SSM / Mamba layers keep their contiguous per-slot state (it has no sequence
axis to page) and are routed around: their cache leaves stay ``[n, B, ...]``
dense and only ``pk``/``pv`` leaves are pooled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1  # unallocated table entry; wraps to the trash block on gather

# allocator-state keys riding at the top level of a paged cache pytree
BSTATE_KEYS = ("tbl", "free", "n_free", "ref", "slot_active")


# ---------------------------------------------------------------------------
# Block-state construction
# ---------------------------------------------------------------------------

def init_block_state(slots: int, max_blocks: int, num_blocks: int) -> dict:
    """Zeroed allocator state: empty tables, fully-free stack, zero refs."""
    return {
        "tbl": jnp.full((slots, max_blocks), NEG, jnp.int32),
        "free": jnp.arange(num_blocks, dtype=jnp.int32),
        "n_free": jnp.int32(num_blocks),
        "ref": jnp.zeros((num_blocks,), jnp.int32),
        "slot_active": jnp.zeros((slots,), bool),
    }


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows."""
    return -(-n_tokens // block_size)


def _free_newly_zero(free, n_free, ref_old, ref_new):
    """Push blocks whose refcount just reached zero back on the free stack
    (ascending block-id order, deterministic)."""
    NB = free.shape[0]
    hit = (ref_old > 0) & (ref_new == 0)
    rank = jnp.cumsum(hit.astype(jnp.int32))        # 1-based push rank
    dest = jnp.where(hit, n_free + rank - 1, NB)    # out-of-range -> dropped
    free = free.at[dest].set(jnp.arange(NB, dtype=free.dtype), mode="drop")
    return free, n_free + jnp.sum(hit.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Decode-time allocation / release (jit-safe, called inside the dispatch)
# ---------------------------------------------------------------------------

def alloc_step(bstate: dict, lengths: jnp.ndarray, block_size: int,
               cap: int, ring: bool, cow: bool = False):
    """One decode step's allocation + write routing, fused.

    Pops a fresh block for every active slot whose write position lands in
    an unallocated table entry (one write per slot per step, so at most one
    block per slot); pool exhaustion leaves the entry unallocated and the
    write then lands in the trash block instead of corrupting the pool.

    With ``cow=True`` a write position landing in a block with ``ref > 1``
    (shared through the prefix cache) also pops a fresh block: the table
    entry is rewired to the copy, the shared block's refcount drops by one,
    and the old id is reported as ``cow_src`` so the layer write can copy
    the block's rows before appending.  ``cow_src == wblk`` marks "no copy"
    (the copy is then the identity).

    Returns ``(bstate, wblk [B], woff [B], cow_src [B])`` — the per-slot
    write target for this step's KV row.  ``cap`` is the logical per-slot
    capacity (``max_blocks * block_size``); ``ring`` maps positions modulo
    ``cap`` (SWA ring semantics).  Inactive slots and positions beyond
    capacity are routed to the trash block.
    """
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    ref = bstate["ref"]
    B, MB = tbl.shape
    trash = free.shape[0]                       # pool index num_blocks
    pos = lengths % cap if ring else lengths
    valid = bstate["slot_active"] & (pos < cap)
    j = jnp.clip(pos // block_size, 0, MB - 1)
    bidx = jnp.arange(B)
    cur = tbl[bidx, j]
    have = cur >= 0
    if cow:
        shared = valid & have & (ref[jnp.clip(cur, 0, trash - 1)] > 1)
    else:
        shared = jnp.zeros((B,), bool)
    need = valid & (~have | shared)
    k = jnp.cumsum(need.astype(jnp.int32))      # 1-based pop rank per slot
    ok = need & (k <= n_free)
    ids = free[jnp.clip(n_free - k, 0, trash - 1)]
    blk = jnp.where(ok, ids, cur)
    tbl = tbl.at[bidx, j].set(blk)
    ref = ref.at[jnp.where(ok, ids, trash)].set(1, mode="drop")
    # a successful CoW pop releases one reference on the shared source;
    # ref stays >= 1 there (the prefix index / other sharers still hold it)
    dec = shared & ok
    ref = ref.at[jnp.where(dec, cur, trash)].add(-1, mode="drop")
    n_free = n_free - jnp.sum(ok.astype(jnp.int32))
    # a shared target whose CoW pop failed (pool dry) must NOT be written:
    # route to trash rather than corrupting the other owners' rows.  The
    # engine's reservation ledger counts one spare block per potential CoW,
    # so this path is unreachable in normal operation.
    writable = valid & (blk >= 0) & ~(shared & ~ok)
    wblk = jnp.where(writable, blk, trash)
    woff = pos % block_size
    cow_src = jnp.where(dec, cur, wblk)
    return ({**bstate, "tbl": tbl, "ref": ref, "n_free": n_free},
            wblk, woff, cow_src)


def release_slots(bstate: dict, done: jnp.ndarray) -> dict:
    """Drop one reference on every block of the ``done`` slots' tables and
    push the blocks whose refcount reaches zero back on the free stack;
    clear the table rows + active flags.  Blocks still held elsewhere (other
    slots' tables, the host prefix index) survive with ``ref >= 1``.  Safe
    to call with slots that own nothing (idempotent)."""
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    ref = bstate["ref"]
    NB = free.shape[0]
    mask = done[:, None] & (tbl >= 0)
    ids = jnp.where(mask, tbl, NB).reshape(-1)
    new_ref = ref.at[ids].add(-1, mode="drop")
    free, n_free = _free_newly_zero(free, n_free, ref, new_ref)
    tbl = jnp.where(done[:, None], NEG, tbl)
    active = bstate["slot_active"] & ~done
    return {**bstate, "tbl": tbl, "free": free, "n_free": n_free,
            "ref": new_ref, "slot_active": active}


def release_refs(bstate: dict, ids: jnp.ndarray) -> dict:
    """Drop one host-side hold per id in ``ids`` (``-1`` entries ignored)
    and free blocks reaching refcount zero — the prefix-cache eviction path
    and the duplicate-registration unwind.  Duplicate ids accumulate."""
    free, n_free, ref = bstate["free"], bstate["n_free"], bstate["ref"]
    NB = free.shape[0]
    new_ref = ref.at[jnp.where(ids >= 0, ids, NB)].add(-1, mode="drop")
    free, n_free = _free_newly_zero(free, n_free, ref, new_ref)
    return {**bstate, "free": free, "n_free": n_free, "ref": new_ref}


# ---------------------------------------------------------------------------
# Admission-time allocation (jit-safe, called from the engine)
# ---------------------------------------------------------------------------

def alloc_admit(bstate: dict, slots: jnp.ndarray, counts: jnp.ndarray,
                nbl: int):
    """Allocate ``counts[i]`` blocks for each admitted slot ``slots[i]``.

    Returns ``(bstate, wids [g, nbl])`` — per-slot write-block ids padded
    with the trash index beyond ``counts[i]`` (the prefill scatter writes
    ``nbl`` block rows per slot; rows past the slot's true need are pad
    garbage and belong in the trash).  The caller (engine) reserves
    capacity on the host, so the stack cannot underflow here.
    """
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    ref = bstate["ref"]
    g = slots.shape[0]
    trash = free.shape[0]
    offs = jnp.cumsum(counts)                   # [g] blocks consumed so far
    jj = jnp.arange(nbl)[None, :]               # [1, nbl]
    pos = n_free - offs[:, None] + jj           # stack index per (slot, j)
    take = jj < counts[:, None]
    ids = free[jnp.clip(pos, 0, trash - 1)]
    wids = jnp.where(take, ids, trash)
    new_rows = jnp.where(take, ids, NEG)
    tbl = tbl.at[slots].set(
        jnp.pad(new_rows, ((0, 0), (0, tbl.shape[1] - nbl)),
                constant_values=NEG))
    ref = ref.at[jnp.where(take, ids, trash).reshape(-1)].set(1, mode="drop")
    n_free = n_free - jnp.sum(counts)
    active = bstate["slot_active"].at[slots].set(True)
    return {**bstate, "tbl": tbl, "ref": ref, "n_free": n_free,
            "slot_active": active}, wids


def admit_slot(bstate: dict, slot, shared_ids: jnp.ndarray, n_shared,
               n_new, n_retained, nbl: int):
    """Admit one request into ``slot`` for chunked / prefix-cached prefill.

    Builds the slot's table row as ``[shared_ids[:n_shared], <n_new popped
    blocks>, NEG...]`` — shared blocks (prefix hits) get ``ref += 1``
    without consuming pool capacity; popped blocks get ``ref = 1``, except
    the first ``n_retained`` which get ``ref = 2``: one table reference
    plus one **prospective prefix-index hold** (the host registers their
    content once the prompt finishes prefilling; pre-retaining at admission
    keeps them alive even if the slot drains inside the same dispatch).

    The slot stays ``slot_active = False`` (prefill phase: decode-step
    writes route to trash until the first token is sampled in-scan).
    Returns ``(bstate, new_ids [nbl])`` — popped ids, ``-1`` padded, in
    table order — for the host to register.
    """
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    ref = bstate["ref"]
    NB = free.shape[0]
    jj = jnp.arange(nbl)
    take = jj < n_new
    pop_ids = jnp.where(take, free[jnp.clip(n_free - 1 - jj, 0, NB - 1)], NEG)
    row = jnp.where(jj < n_shared, shared_ids, NEG)
    sel = jnp.clip(jj - n_shared, 0, nbl - 1)
    in_new = (jj >= n_shared) & (jj < n_shared + n_new)
    row = jnp.where(in_new, pop_ids[sel], row)
    tbl = tbl.at[slot].set(
        jnp.pad(row, (0, tbl.shape[1] - nbl), constant_values=NEG))
    ref = ref.at[jnp.where(jj < n_shared, shared_ids, NB)].add(
        1, mode="drop")
    ref = ref.at[jnp.where(take, pop_ids, NB)].set(
        jnp.where(jj < n_retained, 2, 1), mode="drop")
    n_free = n_free - n_new
    active = bstate["slot_active"].at[slot].set(False)
    return {**bstate, "tbl": tbl, "ref": ref, "n_free": n_free,
            "slot_active": active}, pop_ids


def alloc_span(bstate: dict, lengths: jnp.ndarray, width: int,
               block_size: int, cap: int, ring: bool, cow: bool = False):
    """Ensure each active slot's table covers rows ``[lengths[b],
    lengths[b] + width)`` — the speculative round's write span (engine/
    spec.py): the draft writes up to ``width - 1`` rows past the slot's
    length and the verify forward one more, so the blocks are popped *once*
    per round here and every write inside the round (draft ``alloc_step``
    calls included) then finds its entry allocated and pops nothing.

    With ``cow=True`` (prefix caching composed with speculation) the span's
    *first* entry — the block holding row ``lengths[b]`` — may be a
    partially-filled prompt block shared through the prefix cache
    (``ref > 1``).  Only that entry can ever be shared: later span entries
    cover rows past the slot's length, and shared blocks only enter a table
    at admission, covering prompt rows ``< lengths``.  A shared first entry
    gets the same treatment ``alloc_step`` gives a shared decode target:
    pop a private block, rewire the table, drop one reference on the
    source, and report the pair so the round copies the block's rows
    *before* any draft/verify write lands (models/lm.py
    ``cow_copy_blocks``).

    Rows at or beyond ``cap`` need no block (their writes trash-route, and
    the engine only emits tokens whose positions fit).  Ring (SWA) tables
    are fully allocated at admission, so the ring case pops and copies
    nothing.  Pool exhaustion leaves entries unallocated (writes then
    trash-route); the engine's reservation ledger counts the speculative
    span — and one CoW spare per partial prefix hit — into each slot's
    worst case, so that path is unreachable in normal operation.  Blocks
    stay in the slot's table after a rejection rolls the length back — the
    slot grows into them, and ``release_slots`` returns them when it
    drains.

    Returns ``(bstate, cow_src [B], cow_dst [B], blocked [B])``:
    ``cow_src != cow_dst`` marks a slot whose first span block must be
    copied ``src -> dst`` (both are the trash index when nothing CoWed);
    ``blocked`` marks slots whose shared first block could NOT be copied
    (pool dry) — their table still points at the shared block, so the
    caller must mask them out of the round entirely rather than let a
    draft/verify write corrupt rows other owners read.
    """
    B = bstate["tbl"].shape[0]
    trash = bstate["free"].shape[0]
    no_copy = jnp.full((B,), trash, jnp.int32)
    if ring:
        return bstate, no_copy, no_copy, jnp.zeros((B,), bool)
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    ref = bstate["ref"]
    MB = tbl.shape[1]
    nbl = width // block_size + 2            # static: span-straddle bound
    jj = jnp.arange(nbl)[None, :]            # [1, nbl]
    j = lengths[:, None] // block_size + jj  # candidate table entries
    jc = jnp.clip(j, 0, MB - 1)
    in_span = (j * block_size < jnp.minimum(lengths[:, None] + width, cap)) \
        & (j < MB)
    cur = jnp.take_along_axis(tbl, jc, axis=1)
    if cow:
        shared = (bstate["slot_active"][:, None] & in_span & (jj == 0)
                  & (cur >= 0)
                  & (ref[jnp.clip(cur, 0, trash - 1)] > 1))
    else:
        shared = jnp.zeros((B, nbl), bool)
    need = bstate["slot_active"][:, None] & in_span & ((cur < 0) | shared)
    k = jnp.cumsum(need.reshape(-1).astype(jnp.int32)).reshape(B, nbl)
    ok = need & (k <= n_free)
    ids = free[jnp.clip(n_free - k, 0, trash - 1)]
    new_rows = jnp.where(ok, ids, cur)
    # per-row candidate entries ``j`` are distinct, so the scatter has no
    # duplicate targets; out-of-table entries drop, untouched entries
    # rewrite their own value
    tbl = tbl.at[jnp.arange(B)[:, None], j].set(
        jnp.where(in_span, new_rows, cur), mode="drop")
    ref = ref.at[jnp.where(ok, ids, trash)].set(1, mode="drop")
    # a successful CoW pop releases one reference on the shared source;
    # ref stays >= 1 there (the prefix index / other sharers still hold it)
    dec = shared & ok
    ref = ref.at[jnp.where(dec, cur, trash)].add(-1, mode="drop")
    n_free = n_free - jnp.sum(ok.astype(jnp.int32))
    cow_src = jnp.where(dec[:, 0], cur[:, 0], no_copy)
    cow_dst = jnp.where(dec[:, 0], new_rows[:, 0], no_copy)
    blocked = shared[:, 0] & ~ok[:, 0]
    return ({**bstate, "tbl": tbl, "ref": ref, "n_free": n_free},
            cow_src, cow_dst, blocked)


# ---------------------------------------------------------------------------
# Prefill-chunk write routing (no allocation: admission preallocated)
# ---------------------------------------------------------------------------

def span_targets(bstate: dict, start: jnp.ndarray, valid: jnp.ndarray,
                 width: int, block_size: int, cap: int, ring: bool,
                 shared_until=None):
    """Write targets for a prefill chunk: rows ``start[b] .. start[b] +
    valid[b] - 1`` map through the slot's (preallocated) table row.

    Returns ``(wblk [B, width], woff [B, width])``.  Rows beyond ``valid``,
    beyond capacity, or in unallocated entries are routed to the trash
    block — as are rows below ``shared_until[b]``, the slot's prefix-hit
    watermark: those positions live in blocks *shared* through the prefix
    cache (a matched row recomputed only for its logits), and the cached
    row already holds the identical KV, so the write is dropped instead of
    mutating a block other owners read.
    """
    tbl = bstate["tbl"]
    B, MB = tbl.shape
    NB = bstate["ref"].shape[0]
    jj = jnp.arange(width)[None, :]
    pos = start[:, None] + jj
    rpos = pos % cap if ring else pos
    ok = (jj < valid[:, None]) & (rpos < cap)
    if shared_until is not None:
        ok = ok & (pos >= shared_until[:, None])
    j = jnp.clip(rpos // block_size, 0, MB - 1)
    blk = jnp.take_along_axis(tbl, j, axis=1)
    ok = ok & (blk >= 0)
    wblk = jnp.where(ok, blk, NB)
    woff = rpos % block_size
    return wblk, woff


# ---------------------------------------------------------------------------
# Gather
# ---------------------------------------------------------------------------

def gather_blocks(pool: jnp.ndarray, tbl: jnp.ndarray) -> jnp.ndarray:
    """pool [NB+1, bs, Kv, hd], tbl [B, MB] -> [B, MB*bs, Kv, hd].

    Unallocated entries (-1) wrap to the trash block; callers mask by
    length, so its garbage never reaches the softmax.
    """
    B, MB = tbl.shape
    bs = pool.shape[1]
    g = pool[tbl]                               # [B, MB, bs, Kv, hd]
    return g.reshape(B, MB * bs, *pool.shape[2:])
