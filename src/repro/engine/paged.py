"""Paged KV-cache: global block pool, per-slot block tables, device free-list.

The contiguous engine cache reserves ``cache_len`` rows per slot per layer,
so the longest admissible request dictates the memory of every slot.  The
paged cache replaces the per-slot rows with a **global pool of fixed-size
token blocks** shared by all slots:

* per attention layer: ``pk`` / ``pv`` pools of shape
  ``[num_blocks + 1, block_size, Kv, hd]`` — the extra last block is a
  **trash sink**: writes for inactive slots (the dispatch keeps decoding
  finished slots, same as the contiguous engine) and capacity overflows are
  routed there instead of corrupting live blocks;
* one **block table** ``tbl [slots, max_blocks]`` shared by every attention
  layer (all layers advance in lockstep, so one table serves the stack);
  ``-1`` marks an unallocated entry and — because jnp gathers wrap negative
  indices — conveniently gathers the trash block, whose garbage the length
  mask then discards;
* a **device-resident free-list** ``free [num_blocks]`` (a stack of block
  ids) with scalar stack pointer ``n_free``: blocks are popped inside the
  jitted decode step the moment a slot's length crosses a block boundary and
  pushed back inside the K-step scan the moment a slot's budget drains — so
  capacity recycles mid-dispatch, without a host round-trip.

Everything here is shape-static jit-safe jnp; per-layer wiring lives in
``models/lm.py`` (``init_paged_cache`` / ``decode_step_paged``) and the
host-side admission policy in ``engine.py``.

SSM / Mamba layers keep their contiguous per-slot state (it has no sequence
axis to page) and are routed around: their cache leaves stay ``[n, B, ...]``
dense and only ``pk``/``pv`` leaves are pooled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1  # unallocated table entry; wraps to the trash block on gather

# allocator-state keys riding at the top level of a paged cache pytree
BSTATE_KEYS = ("tbl", "free", "n_free", "slot_active")


# ---------------------------------------------------------------------------
# Block-state construction
# ---------------------------------------------------------------------------

def init_block_state(slots: int, max_blocks: int, num_blocks: int) -> dict:
    """Zeroed allocator state: empty tables, fully-free stack."""
    return {
        "tbl": jnp.full((slots, max_blocks), NEG, jnp.int32),
        "free": jnp.arange(num_blocks, dtype=jnp.int32),
        "n_free": jnp.int32(num_blocks),
        "slot_active": jnp.zeros((slots,), bool),
    }


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows."""
    return -(-n_tokens // block_size)


# ---------------------------------------------------------------------------
# Decode-time allocation / release (jit-safe, called inside the dispatch)
# ---------------------------------------------------------------------------

def alloc_step(bstate: dict, lengths: jnp.ndarray, block_size: int,
               cap: int, ring: bool):
    """One decode step's allocation + write routing, fused.

    Pops a fresh block for every active slot whose write position lands in
    an unallocated table entry (one write per slot per step, so at most one
    block per slot); pool exhaustion leaves the entry unallocated and the
    write then lands in the trash block instead of corrupting the pool.

    Returns ``(bstate, wblk [B], woff [B])`` — the per-slot write target
    for this step's KV row.  ``cap`` is the logical per-slot capacity
    (``max_blocks * block_size``); ``ring`` maps positions modulo ``cap``
    (SWA ring semantics).  Inactive slots and positions beyond capacity are
    routed to the trash block.
    """
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    B, MB = tbl.shape
    trash = free.shape[0]                       # pool index num_blocks
    pos = lengths % cap if ring else lengths
    valid = bstate["slot_active"] & (pos < cap)
    j = jnp.clip(pos // block_size, 0, MB - 1)
    bidx = jnp.arange(B)
    cur = tbl[bidx, j]
    need = valid & (cur < 0)
    k = jnp.cumsum(need.astype(jnp.int32))      # 1-based pop rank per slot
    ok = need & (k <= n_free)
    ids = free[jnp.clip(n_free - k, 0, trash - 1)]
    blk = jnp.where(ok, ids, cur)
    tbl = tbl.at[bidx, j].set(blk)
    n_free = n_free - jnp.sum(ok.astype(jnp.int32))
    wblk = jnp.where(valid & (blk >= 0), blk, trash)
    woff = pos % block_size
    return {**bstate, "tbl": tbl, "n_free": n_free}, wblk, woff


def release_slots(bstate: dict, done: jnp.ndarray) -> dict:
    """Push every block of the ``done`` slots back on the free stack and
    clear their table rows + active flags.  Safe to call with slots that own
    nothing (idempotent)."""
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    mask = (done[:, None] & (tbl >= 0)).reshape(-1)
    ids = tbl.reshape(-1)
    rank = jnp.cumsum(mask.astype(jnp.int32))   # 1-based push rank
    # out-of-range destinations are dropped by the scatter (mode=drop),
    # which is exactly what non-freed entries want
    dest = jnp.where(mask, n_free + rank - 1, free.shape[0])
    free = free.at[dest].set(ids, mode="drop")
    n_free = n_free + jnp.sum(mask.astype(jnp.int32))
    tbl = jnp.where(done[:, None], NEG, tbl)
    active = bstate["slot_active"] & ~done
    return {**bstate, "tbl": tbl, "free": free, "n_free": n_free,
            "slot_active": active}


# ---------------------------------------------------------------------------
# Admission-time allocation (jit-safe, called from the engine's scatter)
# ---------------------------------------------------------------------------

def alloc_admit(bstate: dict, slots: jnp.ndarray, counts: jnp.ndarray,
                nbl: int):
    """Allocate ``counts[i]`` blocks for each admitted slot ``slots[i]``.

    Returns ``(bstate, wids [g, nbl])`` — per-slot write-block ids padded
    with the trash index beyond ``counts[i]`` (the prefill scatter writes
    ``nbl`` block rows per slot; rows past the slot's true need are pad
    garbage and belong in the trash).  The caller (engine) reserves
    capacity on the host, so the stack cannot underflow here.
    """
    tbl, free, n_free = bstate["tbl"], bstate["free"], bstate["n_free"]
    g = slots.shape[0]
    trash = free.shape[0]
    offs = jnp.cumsum(counts)                   # [g] blocks consumed so far
    jj = jnp.arange(nbl)[None, :]               # [1, nbl]
    pos = n_free - offs[:, None] + jj           # stack index per (slot, j)
    take = jj < counts[:, None]
    ids = free[jnp.clip(pos, 0, trash - 1)]
    wids = jnp.where(take, ids, trash)
    new_rows = jnp.where(take, ids, NEG)
    tbl = tbl.at[slots].set(
        jnp.pad(new_rows, ((0, 0), (0, tbl.shape[1] - nbl)),
                constant_values=NEG))
    n_free = n_free - jnp.sum(counts)
    active = bstate["slot_active"].at[slots].set(True)
    return {**bstate, "tbl": tbl, "n_free": n_free,
            "slot_active": active}, wids


# ---------------------------------------------------------------------------
# Gather
# ---------------------------------------------------------------------------

def gather_blocks(pool: jnp.ndarray, tbl: jnp.ndarray) -> jnp.ndarray:
    """pool [NB+1, bs, Kv, hd], tbl [B, MB] -> [B, MB*bs, Kv, hd].

    Unallocated entries (-1) wrap to the trash block; callers mask by
    length, so its garbage never reaches the softmax.
    """
    B, MB = tbl.shape
    bs = pool.shape[1]
    g = pool[tbl]                               # [B, MB, bs, Kv, hd]
    return g.reshape(B, MB * bs, *pool.shape[2:])
