"""Host-side prefix cache: content-chained block hashes -> device block ids.

vLLM-style chained hashing: block ``i`` of a prompt is keyed by
``hash(parent_key, tokens[i*bs:(i+1)*bs])`` so a key identifies the block's
content *and everything before it* — two prompts share block ``i`` iff their
first ``(i+1)*bs`` tokens are identical.  Only **full** blocks are
registered (a partial last block would have its generated tokens appended,
so its content is not a pure function of the prompt).

The index is pure host bookkeeping; device truth lives in the allocator's
refcount array (``engine/paged.py``).  Each registered block contributes
one device reference (the "index hold", pre-retained at admission by
``admit_slot(n_retained=...)``), so finished requests' prompt blocks stay
cached instead of returning to the free stack.  Eviction (LRU over
registration/last-hit order) drops the hold via ``release_refs`` and the
block frees once no live slot references it.

``match`` resolves a new prompt against the index:

* **full-block hits**: the longest chain of leading full blocks already
  registered;
* a **partial tail hit**: when the remaining tail (< one block) equals the
  first ``len(tail)`` tokens of some registered child of the last matched
  chain node, that block is mapped too — the admitted slot then owns a
  *shared partially-relevant block* and its first write triggers the
  allocator's copy-on-write path: ``alloc_step`` for a plain decode
  write, ``alloc_span(cow=True)`` when the slot speculates (the
  speculative round's whole write span CoWs up front, before any draft
  write lands — see ``engine/spec.py``).

The engine tracks which live slots reference each entry (``pin``/``unpin``)
so eviction never pulls a block out from under a running request.
"""
from __future__ import annotations

from dataclasses import dataclass, field


_ROOT = "root"


def chain_hashes(tokens, block_size: int) -> list[tuple]:
    """Chained keys of every *full* block of ``tokens``."""
    keys, parent = [], _ROOT
    for i in range(len(tokens) // block_size):
        blk = tuple(int(t) for t in tokens[i * block_size:(i + 1) * block_size])
        parent = hash((parent, blk))
        keys.append(parent)
    return keys


@dataclass
class _Entry:
    block: int                 # device block id
    tokens: tuple              # the block's token content
    parent: object             # parent chain key (or _ROOT)
    pins: int = 0              # live slots referencing this entry


@dataclass
class PrefixIndex:
    block_size: int
    _entries: dict = field(default_factory=dict)    # chain key -> _Entry
    _children: dict = field(default_factory=dict)   # parent key -> set(keys)
    _lru: dict = field(default_factory=dict)        # key -> tick (ordered)
    _tick: int = 0
    hits: int = 0               # full-block hits served
    partial_hits: int = 0
    evictions: int = 0

    # -- bookkeeping --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def _touch(self, key) -> None:
        self._tick += 1
        self._lru[key] = self._tick

    def block_of(self, key) -> int:
        return self._entries[key].block

    # -- matching -----------------------------------------------------------

    def match(self, tokens) -> tuple[list[int], int | None, list]:
        """Resolve ``tokens`` against the index.

        Returns ``(full_block_ids, partial_block_id, keys)``: the device ids
        of the longest chain of matched leading full blocks, (optionally) a
        registered block whose content starts with the remaining partial
        tail, and the chain keys of every matched entry (for ``pin``).
        Matched entries are LRU-touched and must then be ``pin``-ed by the
        caller for the request's lifetime.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        full_ids, keys, parent = [], [], _ROOT
        n_full = len(toks) // bs
        for i in range(n_full):
            blk = tuple(toks[i * bs:(i + 1) * bs])
            key = hash((parent, blk))
            e = self._entries.get(key)
            if e is None or e.tokens != blk:
                break
            full_ids.append(e.block)
            keys.append(key)
            self._touch(key)
            parent = key
        partial_id = None
        tail = tuple(toks[len(full_ids) * bs:])
        if tail and len(full_ids) == n_full:
            for key in self._children.get(parent, ()):
                e = self._entries[key]
                if e.tokens[:len(tail)] == tail:
                    partial_id = e.block
                    keys.append(key)
                    self._touch(key)
                    self.partial_hits += 1
                    break
        self.hits += len(full_ids)
        return full_ids, partial_id, keys

    def keys_for(self, tokens, n_blocks: int) -> list[tuple]:
        """Chain keys of the first ``n_blocks`` full blocks of ``tokens``."""
        return chain_hashes(tokens, self.block_size)[:n_blocks]

    # -- registration -------------------------------------------------------

    def register(self, tokens, block_ids: list[int],
                 first_block: int) -> list[int]:
        """Register full prompt blocks ``first_block..`` of ``tokens`` under
        ``block_ids`` (one id per block, in order).  Returns the ids that
        were **duplicates** — an equal-content entry already existed, so the
        caller must drop the pre-retained index hold on the redundant copy
        (``release_refs``) and keep the original.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        keys = chain_hashes(toks, bs)
        dups = []
        for j, bid in enumerate(block_ids):
            i = first_block + j
            key = keys[i]
            if key in self._entries:
                dups.append(bid)
                continue
            blk = tuple(toks[i * bs:(i + 1) * bs])
            parent = keys[i - 1] if i else _ROOT
            self._entries[key] = _Entry(bid, blk, parent)
            self._children.setdefault(parent, set()).add(key)
            self._touch(key)
        return dups

    # -- pinning (live-slot references) -------------------------------------

    def pin(self, keys) -> None:
        for k in keys:
            if k in self._entries:
                self._entries[k].pins += 1

    def unpin(self, keys) -> None:
        for k in keys:
            e = self._entries.get(k)
            if e is not None and e.pins > 0:
                e.pins -= 1

    # -- eviction -----------------------------------------------------------

    def evict(self, want: int) -> list[int]:
        """Evict up to ``want`` unpinned entries in LRU order; an entry is
        only evictable when no live slot references it AND it has no
        registered children (children chain through their parent, and
        evicting leaf-first keeps every remaining entry reachable).
        Returns the device block ids whose index hold must be released."""
        freed: list[int] = []
        order = sorted(self._lru, key=self._lru.get)   # one sort per call
        progress = True
        while len(freed) < want and progress:
            progress = False
            for key in order:
                e = self._entries.get(key)
                if e is None or e.pins or self._children.get(key):
                    continue   # gone, live-referenced, or has children
                self._entries.pop(key)
                self._lru.pop(key, None)
                self._children.get(e.parent, set()).discard(key)
                if not self._children.get(e.parent):
                    self._children.pop(e.parent, None)
                freed.append(e.block)
                self.evictions += 1
                progress = True
                if len(freed) >= want:
                    break
        return freed
