"""Token samplers for the serving engine.

One jit-safe entry point :func:`sample` maps ``logits [..., V]`` to next-token
ids ``[...]`` under a static :class:`SamplingParams`:

* **greedy** — argmax (bit-identical to the pre-engine host loop);
* **temperature** — softmax sampling at ``temperature`` via
  ``jax.random.categorical``;
* **top-k** — logits outside the per-row top-k are masked to -inf before the
  categorical draw;
* **top-p (nucleus)** — after temperature, only the smallest set of tokens
  whose cumulative probability reaches ``top_p`` stays unmasked (the top
  token always survives; ties at the cut keep every equal-valued token).

:func:`warp_logits` exposes the shared distribution transform (top-k mask →
temperature → top-p mask) and :func:`probs` its normalized probabilities —
the speculative decoder's lossless rejection sampler needs the *warped*
draft and target distributions, not the raw logits, so both the accept test
and the residual draw see exactly what :func:`sample` would have sampled
from (engine/spec.py).

``SamplingParams`` is a frozen (hashable) dataclass so decode dispatches can
close over it and stay a single jit cache entry; the PRNG key is threaded by
the caller (the engine splits one key per decode step inside its scan).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0          # 0 = no truncation
    top_p: float = 1.0      # 1.0 = no nucleus truncation

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0:
            raise ValueError("temperature must be > 0 for sampling; "
                             "use greedy=True for argmax decoding")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def warp_logits(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """The sampling distribution's logits (fp32): top-k mask, then
    temperature, then top-p mask.  ``categorical(warp_logits(l))`` is what
    :func:`sample` draws for non-greedy params."""
    l32 = logits.astype(jnp.float32)
    V = l32.shape[-1]
    if 0 < sp.top_k < V:
        kth = jax.lax.top_k(l32, sp.top_k)[0][..., -1:]
        l32 = jnp.where(l32 < kth, NEG_INF, l32)
    l32 = l32 / sp.temperature
    if sp.top_p < 1.0:
        srt = jnp.sort(l32, axis=-1)[..., ::-1]           # descending
        ps = jax.nn.softmax(srt, axis=-1)
        cume = jnp.cumsum(ps, axis=-1) - ps               # mass BEFORE token
        keep = cume < sp.top_p                            # top token always
        thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        l32 = jnp.where(l32 < thr, NEG_INF, l32)
    return l32


def probs(logits: jnp.ndarray, sp: SamplingParams) -> jnp.ndarray:
    """Normalized warped sampling distribution [..., V] (fp32)."""
    return jax.nn.softmax(warp_logits(logits, sp), axis=-1)


def sample(logits: jnp.ndarray, key, sp: SamplingParams) -> jnp.ndarray:
    """logits [..., V] -> token ids [...] (int32).  jit- and scan-safe."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, warp_logits(logits, sp),
                                  axis=-1).astype(jnp.int32)
