"""Token samplers for the serving engine.

One jit-safe entry point :func:`sample` maps ``logits [B, V]`` to next-token
ids ``[B]`` under a static :class:`SamplingParams`:

* **greedy** — argmax (bit-identical to the pre-engine host loop);
* **temperature** — softmax sampling at ``temperature`` via
  ``jax.random.categorical``;
* **top-k** — logits outside the per-row top-k are masked to -inf before the
  categorical draw.

``SamplingParams`` is a frozen (hashable) dataclass so decode dispatches can
close over it and stay a single jit cache entry; the PRNG key is threaded by
the caller (the engine splits one key per decode step inside its scan).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0          # 0 = no truncation

    def __post_init__(self):
        if not self.greedy and self.temperature <= 0:
            raise ValueError("temperature must be > 0 for sampling; "
                             "use greedy=True for argmax decoding")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


def sample(logits: jnp.ndarray, key, sp: SamplingParams) -> jnp.ndarray:
    """logits [..., V] -> token ids [...] (int32).  jit- and scan-safe."""
    if sp.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l32 = logits.astype(jnp.float32)
    V = l32.shape[-1]
    if 0 < sp.top_k < V:
        kth = jax.lax.top_k(l32, sp.top_k)[0][..., -1:]
        l32 = jnp.where(l32 < kth, NEG_INF, l32)
    l32 = l32 / sp.temperature
    return jax.random.categorical(key, l32, axis=-1).astype(jnp.int32)
