"""Device-resident slot scheduler: state layout + multi-token decode dispatch.

The continuous batcher's per-slot state (``cur`` token, ``active`` flag,
``remaining`` budget) lives in jnp arrays and is updated *inside* the jitted
decode dispatch, so the host never round-trips per token.  One dispatch runs
``k_steps`` decode steps under ``lax.scan`` and returns the emitted token
grid ``[B, K]`` plus the emission mask — the host syncs once per K steps
instead of once per slot per token.

Semantics match the pre-engine host loop exactly: every slot decodes every
step (finished slots produce masked garbage that is overwritten at the next
prefill, just as the old loop kept feeding finished slots), ``remaining`` is
decremented only while a slot is active, and a slot deactivates when its
budget reaches zero.  Under greedy sampling the emitted tokens are therefore
token-identical to the old loop.

**Chunked prefill** (``chunk > 0``, paged caches only): prompt prefill rides
*inside* the same ``lax.scan`` — each scan step runs one decode step for the
decoding slots AND one ``chunk``-token prefill piece for the slots still in
prefill phase (state fields ``prompt`` / ``pf_pos`` / ``pf_len``, armed by
the engine's admission).  A long prompt therefore no longer stalls in-flight
decode: it streams through K*chunk prompt tokens per dispatch while other
slots keep emitting.  The step a slot's last chunk lands, its first token is
sampled from the chunk's logits and emitted through the same token grid, and
decoding starts the following step — exactly the contiguous engine's
"prefill, sample first, then decode" order.  Because the two sub-steps share
one batch, each pass restores the rows of slots in the *other* phase
(per-slot lengths and SSM state), so a prefilling slot's accumulating state
is never touched by the decode pass's masked garbage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.paged import BSTATE_KEYS, release_slots
from repro.engine.sampler import SamplingParams, sample
from repro.models.lm import Model
from repro.telemetry.counters import bump, init_counters


def init_slot_state(n_slots: int, prompt_cap: int = 0) -> dict:
    """Zeroed device-side slot state for a fresh pool of ``n_slots``.

    ``prompt_cap > 0`` adds the chunked-prefill fields: a per-slot prompt
    buffer plus prefill cursor/length and the post-first-token decode
    budget (armed by the engine's admission).  ``ctr`` is the
    device-resident telemetry counter tree (repro.telemetry.counters):
    bumped inside the scan, read for free at the existing dispatch sync."""
    st = {
        "cur": jnp.zeros((n_slots, 1), jnp.int32),      # last sampled token
        "active": jnp.zeros((n_slots,), bool),          # slot serving a req?
        "remaining": jnp.zeros((n_slots,), jnp.int32),  # decode budget left
        "ctr": init_counters(),                         # device counters
    }
    if prompt_cap:
        st["prompt"] = jnp.zeros((n_slots, prompt_cap), jnp.int32)
        st["pf_pos"] = jnp.zeros((n_slots,), jnp.int32)   # next prompt row
        st["pf_len"] = jnp.zeros((n_slots,), jnp.int32)   # prompt length
        st["budget"] = jnp.zeros((n_slots,), jnp.int32)   # decode budget
        st["pf_shared"] = jnp.zeros((n_slots,), jnp.int32)  # prefix-hit mark
    return st


def _keep_rows(new_cache: dict, old_cache: dict, keep) -> dict:
    """Merge two paged caches per slot: rows of slots in ``keep`` come from
    ``new_cache``, others are restored from ``old_cache``.  Pool leaves
    (``pk``/``pv``) and the global allocator state stay from ``new_cache``
    (writes of non-kept slots were trash-routed); per-slot leaves (SSM
    state, batch axis 1 under the period axis) and ``lengths`` select."""
    def sel(name, n, o):
        if name in ("pk", "pv"):
            return n
        m = keep.reshape((1, keep.shape[0]) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)

    merged = dict(new_cache)
    for grp in ("stack", "prefix"):
        if grp not in new_cache:
            continue
        merged[grp] = {
            lk: {name: sel(name, lv[name], old_cache[grp][lk][name])
                 for name in lv}
            for lk, lv in new_cache[grp].items()}
    merged["lengths"] = jnp.where(keep, new_cache["lengths"],
                                  old_cache["lengths"])
    return merged


def chunk_prefill_substep(model: Model, sp: SamplingParams, chunk: int,
                          params, st: dict, cache: dict, first_key):
    """One in-scan chunked-prefill piece — the prefill *phase* of a scan
    step, shared by the plain decode dispatch and the speculative dispatch
    (engine/spec.py).

    Runs a ``chunk``-token prefill piece for every slot still in prefill
    phase (``pf_pos < pf_len``), restores the rows of slots in other phases
    (``_keep_rows``), samples the first token of slots whose last chunk
    just landed (from ``first_key``), arms them for decode, and releases
    the blocks of zero-budget slots.  Returns ``(st, cache, first [B],
    completed [B])`` — the caller merges ``first`` into its own token grid
    (the plain dispatch's ``[B, K]`` column, the speculative dispatch's
    round column 0, which the just-completed slot — inactive during the
    round — left free).
    """
    pcap = st["prompt"].shape[1]
    pf_left = st["pf_len"] - st["pf_pos"]
    valid = jnp.clip(pf_left, 0, chunk)
    prefilling = valid > 0
    idx = jnp.clip(st["pf_pos"][:, None] + jnp.arange(chunk)[None],
                   0, pcap - 1)
    toks = jnp.take_along_axis(st["prompt"], idx, axis=1)
    logits_pf, new_cache = model.prefill_chunk_paged(
        params, toks, cache, st["pf_pos"], valid, st["pf_shared"])
    cache = _keep_rows(new_cache, cache, prefilling)
    completed = prefilling & (pf_left <= chunk)
    first = sample(logits_pf, first_key, sp)
    go = completed & (st["budget"] > 0)
    cache = {**cache, "slot_active": cache["slot_active"] | go}
    nf0 = cache["n_free"]
    bstate = release_slots({k: cache[k] for k in BSTATE_KEYS},
                           completed & ~go)
    cache = {**cache, **bstate}
    ctr = bump(st["ctr"],
               tokens=jnp.sum(completed),   # first tokens emit via the grid
               chunk_pieces=jnp.sum(prefilling),
               chunks_completed=jnp.sum(completed),
               blocks_released=cache["n_free"] - nf0)
    st = {**st,
          "cur": jnp.where(completed[:, None], first[:, None], st["cur"]),
          "active": st["active"] | go,
          "remaining": jnp.where(completed, st["budget"], st["remaining"]),
          "pf_pos": st["pf_pos"] + valid,
          "ctr": ctr}
    return st, cache, first, completed


def make_decode_dispatch(model: Model, sp: SamplingParams, k_steps: int,
                         *, paged: bool = False, cow: bool = False,
                         chunk: int = 0, n_spec: int = 0):
    """Build the jitted K-step decode dispatch.

    ``dispatch(params, state, cache, key)`` -> (state, cache, tokens [B, K],
    emitted [B, K] bool).  ``emitted[b, j]`` marks tokens produced while slot
    ``b`` was still active; it is a contiguous prefix per row, so the host
    can append ``tokens[b, emitted[b]]`` verbatim.

    ``n_spec > 0`` swaps each scan step for a **speculative round** (draft
    ``n_spec`` tokens with a quantized tree, verify with one full-precision
    forward — engine/spec.py): the returned dispatch then takes an extra
    ``draft_params`` argument after ``params`` and a runtime ``depth``
    scalar before ``key`` (the dynamic speculation depth, 1..n_spec — a
    plain traced operand, so moving it never recompiles), and its grids
    widen to ``[B, k_steps * (n_spec + 1)]`` (acceptance telemetry rides
    the ``state["ctr"]`` counter tree).  Speculation requires the paged
    cache and
    **composes** with both flags: ``cow=True`` makes the round's span
    allocation copy-on-write (a draft/verify write into a prefix-shared
    block pops a private copy first, exactly like a decode write), and
    ``chunk > 0`` appends the chunked-prefill phase to every round — the
    three are orthogonal phases of one scan step.

    With ``paged=True`` the cache is the paged block pool
    (``model.init_paged_cache``): each step runs ``decode_step_paged`` (which
    pops blocks from the device free-list as slots cross block boundaries)
    and the moment a slot's budget drains its blocks are pushed back **inside
    the scan** — capacity recycles mid-dispatch without a host round-trip.
    ``cow=True`` enables the copy-on-write write path (refcounted prefix
    caching).  ``chunk > 0`` piggybacks chunked prefill on the scan (see
    module docstring); extra state fields ride through untouched either way,
    so the same state pytree serves both dispatch flavors.
    """
    if n_spec:
        if not paged:
            raise NotImplementedError(
                "speculative dispatch needs the paged cache path")
        from repro.engine.spec import make_spec_dispatch
        return make_spec_dispatch(model, sp, k_steps, n_spec, cow=cow,
                                  chunk=chunk)
    if not paged:
        step_fn = model.decode_step
    else:
        if model.decode_step_paged is None:
            raise NotImplementedError(
                f"model family {model.cfg.family!r} has no paged decode path")
        def step_fn(params, toks, cache):
            return model.decode_step_paged(params, toks, cache, cow=cow)
    if chunk:
        if not paged or model.prefill_chunk_paged is None:
            raise NotImplementedError(
                "chunked prefill needs the paged cache path")

    def dispatch(params, state: dict, cache: dict, key):
        def body(carry, step_key):
            st, cache = carry
            ctr = st["ctr"]
            # ---- decode sub-step (slots in decode phase) ----------------
            if paged:   # allocator deltas around the step count pops/CoW
                nf0, ref0 = cache["n_free"], cache["ref"]
            logits, new_cache = step_fn(params, st["cur"], cache)
            if chunk:  # prefilling/idle slots' rows must stay untouched
                new_cache = _keep_rows(new_cache, cache, st["active"])
            cache = new_cache
            nxt = sample(logits, step_key, sp)
            emitted = st["active"]
            remaining = st["remaining"] - emitted.astype(jnp.int32)
            active = emitted & (remaining > 0)
            if paged:
                # alloc_step only pops; a CoW pop is the only ref decrement
                ctr = bump(ctr,
                           blocks_popped=nf0 - cache["n_free"],
                           cow_copies=jnp.sum(cache["ref"] < ref0))
                nf1 = cache["n_free"]
                bstate = release_slots({k: cache[k] for k in BSTATE_KEYS},
                                       emitted & ~active)
                cache = {**cache, **bstate}
                ctr = bump(ctr, blocks_released=cache["n_free"] - nf1)
            tok_out, em_out = nxt, emitted
            st = {**st, "cur": nxt[:, None], "active": active,
                  "remaining": remaining,
                  "ctr": bump(ctr, tokens=jnp.sum(emitted))}
            # ---- chunked-prefill sub-step -------------------------------
            if chunk:
                st, cache, first, completed = chunk_prefill_substep(
                    model, sp, chunk, params, st, cache,
                    jax.random.fold_in(step_key, 1))
                tok_out = jnp.where(completed, first, tok_out)
                em_out = em_out | completed
                st = {**st, "cur": tok_out[:, None]}
            return (st, cache), (tok_out, em_out)

        keys = jax.random.split(key, k_steps)
        (state, cache), (toks, emitted) = jax.lax.scan(
            body, (state, cache), keys)
        return state, cache, toks.T, emitted.T

    return dispatch


def make_decode_step(model: Model, sp: SamplingParams | None = None):
    """One decode step + sampling: (params, tokens, cache, key=) ->
    (next_tok [B, 1], logits [B, V], new cache).

    This is the single-step form of the dispatch; ``launch.steps
    .make_serve_step`` is a deprecated greedy alias of it.
    """
    sp = sp or SamplingParams()

    def step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        k = key if key is not None else jax.random.PRNGKey(0)
        nxt = sample(logits, k, sp)[:, None]
        return nxt, logits, cache

    return step
