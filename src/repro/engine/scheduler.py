"""Device-resident slot scheduler: state layout + multi-token decode dispatch.

The continuous batcher's per-slot state (``cur`` token, ``active`` flag,
``remaining`` budget) lives in jnp arrays and is updated *inside* the jitted
decode dispatch, so the host never round-trips per token.  One dispatch runs
``k_steps`` decode steps under ``lax.scan`` and returns the emitted token
grid ``[B, K]`` plus the emission mask — the host syncs once per K steps
instead of once per slot per token.

Semantics match the pre-engine host loop exactly: every slot decodes every
step (finished slots produce masked garbage that is overwritten at the next
prefill, just as the old loop kept feeding finished slots), ``remaining`` is
decremented only while a slot is active, and a slot deactivates when its
budget reaches zero.  Under greedy sampling the emitted tokens are therefore
token-identical to the old loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.sampler import SamplingParams, sample
from repro.models.lm import Model


def init_slot_state(n_slots: int) -> dict:
    """Zeroed device-side slot state for a fresh pool of ``n_slots``."""
    return {
        "cur": jnp.zeros((n_slots, 1), jnp.int32),      # last sampled token
        "active": jnp.zeros((n_slots,), bool),          # slot serving a req?
        "remaining": jnp.zeros((n_slots,), jnp.int32),  # decode budget left
    }


def make_decode_dispatch(model: Model, sp: SamplingParams, k_steps: int,
                         *, paged: bool = False):
    """Build the jitted K-step decode dispatch.

    ``dispatch(params, state, cache, key)`` -> (state, cache, tokens [B, K],
    emitted [B, K] bool).  ``emitted[b, j]`` marks tokens produced while slot
    ``b`` was still active; it is a contiguous prefix per row, so the host
    can append ``tokens[b, emitted[b]]`` verbatim.

    With ``paged=True`` the cache is the paged block pool
    (``model.init_paged_cache``): each step runs ``decode_step_paged`` (which
    pops blocks from the device free-list as slots cross block boundaries)
    and the moment a slot's budget drains its blocks are pushed back **inside
    the scan** — capacity recycles mid-dispatch without a host round-trip.
    """
    step_fn = model.decode_step_paged if paged else model.decode_step
    if paged and step_fn is None:
        raise NotImplementedError(
            f"model family {model.cfg.family!r} has no paged decode path")

    def dispatch(params, state: dict, cache: dict, key):
        def body(carry, step_key):
            st, cache = carry
            logits, cache = step_fn(params, st["cur"], cache)
            nxt = sample(logits, step_key, sp)
            emitted = st["active"]
            remaining = st["remaining"] - emitted.astype(jnp.int32)
            active = emitted & (remaining > 0)
            if paged:
                from repro.engine.paged import BSTATE_KEYS, release_slots
                bstate = release_slots({k: cache[k] for k in BSTATE_KEYS},
                                       emitted & ~active)
                cache = {**cache, **bstate}
            st = {"cur": nxt[:, None],
                  "active": active,
                  "remaining": remaining}
            return (st, cache), (nxt, emitted)

        keys = jax.random.split(key, k_steps)
        (state, cache), (toks, emitted) = jax.lax.scan(
            body, (state, cache), keys)
        return state, cache, toks.T, emitted.T

    return dispatch


def make_decode_step(model: Model, sp: SamplingParams | None = None):
    """One decode step + sampling: (params, tokens, cache, key=) ->
    (next_tok [B, 1], logits [B, V], new cache).

    This is the single-step form of the dispatch; ``launch.steps
    .make_serve_step`` is a deprecated greedy alias of it.
    """
    sp = sp or SamplingParams()

    def step(params, tokens, cache, key=None):
        logits, cache = model.decode_step(params, tokens, cache)
        k = key if key is not None else jax.random.PRNGKey(0)
        nxt = sample(logits, k, sp)[:, None]
        return nxt, logits, cache

    return step
