"""Self-speculative decoding: the quantized param tree drafts, the
full-precision tree verifies — inside one jitted K-round dispatch.

DAQ's claim is that delta-aware quantization preserves the *behavior* the
fine-tune encoded in small-magnitude ΔW, not just per-tensor reconstruction
error.  This subsystem operationalizes that claim in the serving hot path:
the quantized model (any ``repro.quantize`` registry method — ``daq``,
``absmax``, …) autoregressively drafts ``n_spec`` tokens, one multi-token
verify forward of the full-precision model scores them all, and a prefix is
accepted.  The **draft acceptance rate** is then a data-free, end-to-end,
token-level behavioral-fidelity metric for the quantization method — and
every accepted draft is a decode step the verifier never had to run
serially, so it is also a tok/s win wherever a C-token forward costs less
than C single-token forwards (every memory-bound accelerator).

One speculative **round** (one step of the K-step dispatch scan):

1. **span allocation** — ``paged.alloc_span`` pops the blocks covering the
   round's write span ``[len, len + n_spec + 1)`` once, so neither the
   draft steps nor the verify forward allocate (SWA rings are fully
   allocated at admission already).
2. **draft** — ``n_spec`` ordinary ``decode_step_paged`` calls with the
   quantized tree, scanned on a working copy of the cache.  The draft
   reads the verifier's (full-precision) KV for all history and its own
   fresh rows for the current round; its writes land in the same span the
   verify forward overwrites, so no draft-quality KV ever survives a round.
3. **verify** — one ``model.verify_chunk_paged`` forward of the
   full-precision tree over ``[cur, d_1 .. d_n]`` returns logits at every
   position, each row a bitwise mirror of the decode step the
   non-speculative engine would have run (decode-softmax attention over
   the gathered table, exact per-token SSM recurrence — models/lm.py).
4. **accept** — greedy: the longest prefix with ``argmax(p_i) == d_i``,
   then the verifier's own argmax as correction/bonus.  Sampled: lossless
   rejection sampling over the *warped* (temperature/top-k/top-p)
   distributions — accept ``d_i`` with prob ``min(1, p_i(d)/q_i(d))``,
   sample the first rejection from ``norm(max(p - q, 0))``, the
   all-accepted bonus from ``p_{n+1}`` — so emitted tokens are distributed
   exactly as non-speculative sampling (pinned by an unbiasedness test).
5. **rollback** — rejected positions roll back per slot: ``lengths``
   rewinds to the accepted point (stale KV rows beyond it are masked by
   every later read and overwritten by later writes; their blocks stay in
   the slot's table for the slot to grow into).  Families with recurrent
   or ring state (SSM / hybrid / SWA) cannot rewind by masking alone, so
   they run a **second** verify pass with ``valid = accepted`` over the
   pre-round cache — recomputing exactly the accepted rows' state — while
   pure linear-attention stacks (dense / MoE) keep the first pass's cache
   and only rewind ``lengths``.

Guarantee: greedy speculative output is **token-exact** against the
non-speculative paged engine (and therefore the contiguous engine and the
legacy host loop) for any draft tree whatsoever — the draft only decides
how many verifier-identical tokens emit per round, never their values.

Budget clamp: a round may accept more tokens than the slot's remaining
budget; emission is clamped (``min(accepted + 1, remaining)``) and every
clamped-away position is provably beyond the request's final token, so the
clamp never changes emitted values.  Acceptance counters report the raw
verifier-agreement prefix (the fidelity metric), not the clamped emission.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.paged import BSTATE_KEYS, alloc_span, release_slots
from repro.engine.sampler import SamplingParams, probs, sample
from repro.models.lm import Model


# ---------------------------------------------------------------------------
# Acceptance rules (pure, unit-testable)
# ---------------------------------------------------------------------------

def greedy_accept(drafts: jnp.ndarray, p_logits: jnp.ndarray):
    """Greedy prefix acceptance.

    ``drafts`` [B, n] proposed tokens; ``p_logits`` [B, n+1, V] verifier
    logits (row ``i`` scores proposal ``i``; row ``n`` is the bonus
    position).  Returns ``(out [B, n+1], n_acc [B])``: rows ``< n_acc`` of
    ``out`` are the accepted drafts, row ``n_acc`` the verifier's own
    argmax (the correction after a mismatch, or the bonus token when all
    drafts matched); rows past that are don't-care.
    """
    B, n1 = p_logits.shape[:2]
    n = n1 - 1
    tgt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)       # [B, n+1]
    match = (tgt[:, :n] == drafts).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)             # [B] 0..n
    out = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    fix = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
    return out.at[jnp.arange(B), a].set(fix), a


def rejection_accept(key, drafts: jnp.ndarray, q_logits: jnp.ndarray,
                     p_logits: jnp.ndarray, sp: SamplingParams):
    """Lossless speculative rejection sampling (Leviathan et al.) over the
    **warped** draft/target distributions.

    ``drafts`` [B, n] were sampled from ``probs(q_logits, sp)``; draft ``i``
    is accepted with probability ``min(1, p_i(d_i) / q_i(d_i))``, the first
    rejection is resampled from ``norm(max(p_i - q_i, 0))``, and the
    all-accepted case draws the bonus token from ``p_{n+1}`` (the same
    formula with ``q := 0``).  The emitted-token distribution equals plain
    sampling from the warped target — pinned by a frequency test.
    Returns ``(out [B, n+1], n_acc [B])`` like :func:`greedy_accept`.
    """
    B, n1, V = p_logits.shape
    n = n1 - 1
    qp = probs(q_logits, sp)                                    # [B, n, V]
    pp = probs(p_logits, sp)                                    # [B, n+1, V]
    pd = jnp.take_along_axis(pp[:, :n], drafts[..., None], axis=-1)[..., 0]
    qd = jnp.take_along_axis(qp, drafts[..., None], axis=-1)[..., 0]
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, n))
    accept = (u * qd < pd).astype(jnp.int32)    # P[accept] = min(1, p/q)
    a = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)            # [B] 0..n
    pa = jnp.take_along_axis(pp, a[:, None, None], axis=1)[:, 0]
    q_ext = jnp.concatenate([qp, jnp.zeros((B, 1, V), qp.dtype)], axis=1)
    qa = jnp.take_along_axis(q_ext, a[:, None, None], axis=1)[:, 0]
    r = jnp.maximum(pa - qa, 0.0)
    z = jnp.sum(r, axis=-1, keepdims=True)
    r = jnp.where(z > 0, r / z, pa)             # p == q numerically: use p
    tail = jax.random.categorical(kr, jnp.log(jnp.maximum(r, 1e-38)),
                                  axis=-1).astype(jnp.int32)
    out = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    return out.at[jnp.arange(B), a].set(tail), a


# ---------------------------------------------------------------------------
# The K-round speculative dispatch
# ---------------------------------------------------------------------------

def make_spec_dispatch(model: Model, sp: SamplingParams, k_steps: int,
                       n_spec: int):
    """Build the jitted K-round speculative dispatch.

    ``dispatch(params, draft_params, state, cache, key)`` ->
    ``(state, cache, tokens [B, K*(n_spec+1)], emitted [B, K*(n_spec+1)],
    counts [2])`` — ``emitted[b]`` marks the tokens slot ``b`` really
    produced (a contiguous prefix per round, rounds concatenated in order,
    so the host appends ``tokens[b, emitted[b]]`` verbatim, exactly like
    the plain dispatch's grid).  ``counts`` is ``(drafted, accepted)``
    summed over rounds and slots — the acceptance-rate telemetry.

    The same ``state`` pytree as the plain dispatch is used (``cur`` /
    ``active`` / ``remaining``); blocks of slots that drain mid-dispatch
    are pushed back inside the scan, as in the non-speculative path.
    """
    if model.decode_step_paged is None or model.verify_chunk_paged is None:
        raise NotImplementedError(
            f"model family {model.cfg.family!r} has no paged decode/verify "
            f"path")
    mcfg = model.cfg
    # SSM state is recurrent and SWA rings are position-keyed: rejected
    # rows cannot be rewound by masking, so those families re-run the
    # verify with valid = accepted over the pre-round cache (pass 2)
    two_pass = mcfg.family in ("ssm", "hybrid") or bool(mcfg.sliding_window)
    S1 = n_spec + 1

    def dispatch(params, draft_params, state, cache, key):
        B = state["active"].shape[0]

        def round_body(carry, step_key):
            st, cache = carry
            active = st["active"]
            lengths = cache["lengths"]
            # ---- 1. span allocation (once per round) --------------------
            leaf = next((l for l in cache["stack"].values() if "pk" in l),
                        None)
            if leaf is not None:
                bs = leaf["pk"].shape[2]
                cap = cache["tbl"].shape[1] * bs
                ring = bool(mcfg.sliding_window) and cap == mcfg.sliding_window
                bstate = alloc_span({k: cache[k] for k in BSTATE_KEYS},
                                    lengths, S1, bs, cap, ring)
                cache = {**cache, **bstate}
            # ---- 2. draft (quantized tree, working cache copy) ----------
            def draft_body(dc, dk):
                dcache, cur = dc
                logits, dcache = model.decode_step_paged(draft_params, cur,
                                                         dcache)
                nxt = sample(logits, dk, sp)
                return (dcache, nxt[:, None]), (nxt, logits)

            dkeys = jax.random.split(jax.random.fold_in(step_key, 0), n_spec)
            (dcache, _), (dtoks, dlogits) = jax.lax.scan(
                draft_body, (cache, st["cur"]), dkeys)
            drafts = dtoks.T                                    # [B, n]
            # ---- 3. verify (full-precision tree, one forward) -----------
            vtoks = jnp.concatenate([st["cur"], drafts], axis=1)
            vvalid = jnp.where(active, S1, 0)
            # one-pass families reuse the draft's cache (its span rows are
            # fully overlaid/overwritten by the verify); two-pass families
            # must keep the pre-round cache for the commit pass
            vc_in = {**(cache if two_pass else dcache), "lengths": lengths}
            v_logits, vcache = model.verify_chunk_paged(
                params, vtoks, vc_in, lengths, vvalid)
            # ---- 4. accept ----------------------------------------------
            if sp.greedy:
                out, a = greedy_accept(drafts, v_logits)
            else:
                out, a = rejection_accept(
                    jax.random.fold_in(step_key, 1), drafts,
                    dlogits.transpose(1, 0, 2), v_logits, sp)
            m = jnp.where(active, jnp.minimum(a + 1, st["remaining"]), 0)
            # ---- 5. commit + rollback -----------------------------------
            new_len = jnp.where(active, lengths + m, lengths)
            if two_pass:
                _, ccache = model.verify_chunk_paged(
                    params, vtoks, {**cache, "lengths": lengths}, lengths,
                    m)
                cache = {**ccache, "lengths": new_len}
            else:
                cache = {**vcache, "lengths": new_len}
            # ---- 6. emit + budget ---------------------------------------
            em = active[:, None] & (jnp.arange(S1)[None, :] < m[:, None])
            cur = jnp.take_along_axis(out, jnp.maximum(m - 1, 0)[:, None],
                                      axis=1)
            cur = jnp.where(active[:, None], cur, st["cur"])
            remaining = st["remaining"] - m
            new_active = active & (remaining > 0)
            # ---- 7. recycle drained slots' blocks in-scan ---------------
            bstate = release_slots({k: cache[k] for k in BSTATE_KEYS},
                                   active & ~new_active)
            cache = {**cache, **bstate}
            st = {**st, "cur": cur, "active": new_active,
                  "remaining": remaining}
            drafted = jnp.sum(jnp.where(active, n_spec, 0))
            accepted = jnp.sum(jnp.where(active, a, 0))
            return (st, cache), (out, em, drafted, accepted)

        keys = jax.random.split(key, k_steps)
        (state, cache), (toks, em, dr, ac) = jax.lax.scan(
            round_body, (state, cache), keys)
        toks = toks.transpose(1, 0, 2).reshape(B, k_steps * S1)
        em = em.transpose(1, 0, 2).reshape(B, k_steps * S1)
        return state, cache, toks, em, jnp.stack([jnp.sum(dr), jnp.sum(ac)])

    return dispatch
