"""Self-speculative decoding: the quantized param tree drafts, the
full-precision tree verifies — inside one jitted K-round dispatch, composed
with chunked prefill and refcounted prefix caching.

DAQ's claim is that delta-aware quantization preserves the *behavior* the
fine-tune encoded in small-magnitude ΔW, not just per-tensor reconstruction
error.  This subsystem operationalizes that claim in the serving hot path:
the quantized model (any ``repro.quantize`` registry method — ``daq``,
``absmax``, …) autoregressively drafts up to ``n_spec`` tokens, one
multi-token verify forward of the full-precision model scores them all, and
a prefix is accepted.  The **draft acceptance rate** is then a data-free,
end-to-end, token-level behavioral-fidelity metric for the quantization
method — and every accepted draft is a decode step the verifier never had
to run serially, so it is also a tok/s win wherever a C-token forward costs
less than C single-token forwards (every memory-bound accelerator).

One speculative **round** (one step of the K-step dispatch scan) is built
from orthogonal phases; chunk-prefill pieces and copy-on-write prefix
semantics compose with speculation instead of excluding it:

1. **span allocation (+ CoW)** — ``paged.alloc_span`` pops the blocks
   covering the round's write span ``[len, len + n_spec + 1)`` once, so
   neither the draft steps nor the verify forward allocate (SWA rings are
   fully allocated at admission already).  With prefix caching
   (``cow=True``) the span's first block may be a partially-matched prompt
   block shared through the prefix index: the span allocator then pops a
   private copy, rewires the table, drops one reference on the source —
   exactly what ``alloc_step`` does for a shared decode target — and
   ``models.lm.cow_copy_blocks`` materializes the copy before any write of
   the round lands.  A slot whose CoW pop failed (pool dry; unreachable
   under the engine's reservation ledger) is masked out of the whole round
   and retries next round, so a draft write can never corrupt a block
   other owners read.
2. **draft** — ``n_spec`` ordinary ``decode_step_paged`` calls with the
   quantized tree, scanned on a working copy of the cache.  The draft
   reads the verifier's (full-precision) KV for all history and its own
   fresh rows for the current round; its writes land in the same span the
   verify forward overwrites, so no draft-quality KV ever survives a round.
   Slots still in chunked-prefill phase are not ``slot_active``, so their
   draft writes trash-route and their accumulating state is untouched.
3. **verify** — one ``model.verify_chunk_paged`` forward of the
   full-precision tree over ``[cur, d_1 .. d_n]`` returns logits at every
   position, each row a bitwise mirror of the decode step the
   non-speculative engine would have run (decode-softmax attention over
   the gathered table — prefix-shared full blocks gather like any other —
   exact per-token SSM recurrence; models/lm.py).
4. **accept** — greedy: the longest prefix with ``argmax(p_i) == d_i``,
   then the verifier's own argmax as correction/bonus.  Sampled: lossless
   rejection sampling over the *warped* (temperature/top-k/top-p)
   distributions — accept ``d_i`` with prob ``min(1, p_i(d)/q_i(d))``,
   sample the first rejection from ``norm(max(p - q, 0))``, the
   all-accepted bonus from ``p_{n+1}``.  Both rules take the runtime
   ``depth`` scalar (dynamic speculation depth, see below): positions at
   or beyond ``depth`` are treated as never-proposed (greedy: forced
   mismatch; sampled: rejected with ``q := 0``, so the cutoff position
   resamples from ``p`` itself — the bonus formula), which makes depth-d
   rounds distribution-identical to static ``n_spec = d`` rounds.
5. **rollback** — rejected positions roll back per slot: ``lengths``
   rewinds to the accepted point (stale KV rows beyond it are masked by
   every later read and overwritten by later writes; their blocks stay in
   the slot's table for the slot to grow into).  Families with recurrent
   or ring state (SSM / hybrid / SWA) cannot rewind by masking alone, so
   they run a **second** verify pass with ``valid = accepted`` over the
   pre-round cache — recomputing exactly the accepted rows' state — while
   pure linear-attention stacks (dense / MoE) keep the first pass's cache
   and only rewind ``lengths``.
6. **chunked prefill** (``chunk > 0``) — the same in-scan prefill piece
   the plain dispatch runs (``scheduler.chunk_prefill_substep``): slots in
   prefill phase stream ``chunk`` prompt tokens per round while the other
   slots speculate; the round a slot's last chunk lands its first token is
   emitted through column 0 of the round's grid slice (free — the slot
   was inactive during the speculative phase) and it starts speculating
   the following round.

**Dynamic speculation depth** — the dispatch takes ``depth`` (a traced
``int32`` scalar, 1..n_spec) instead of baking the round depth into the
program: the draft still runs ``n_spec`` steps and the grids stay sized
``n_spec + 1``, so moving ``depth`` between dispatches never changes the
jitted signature (zero recompiles — pinned by the staticcheck fingerprint
manifest and tests), while the acceptance rules mask positions beyond it.
:class:`DepthController` is the host-side policy: it reads the per-dispatch
``drafted`` / ``accepted`` deltas of the device counter tree
(``state["ctr"]``, repro.telemetry.counters — fetched in the same sync as
the token grid) and walks the depth up on sustained high acceptance,
halving it on misses — AIMD on the acceptance rate — so a garbage draft
stops wasting n_spec draft forwards per round without a single retrace.

Guarantee: greedy speculative output is **token-exact** against the
non-speculative paged engine (and therefore the contiguous engine and the
legacy host loop) for any draft tree and any depth trajectory whatsoever —
the draft only decides how many verifier-identical tokens emit per round,
never their values.

Budget clamp: a round may accept more tokens than the slot's remaining
budget; emission is clamped (``min(accepted + 1, remaining)``) and every
clamped-away position is provably beyond the request's final token, so the
clamp never changes emitted values.  Acceptance counters report the raw
verifier-agreement prefix (the fidelity metric) against the *depth*
actually drafted, not the clamped emission.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.engine.paged import BSTATE_KEYS, alloc_span, release_slots
from repro.engine.sampler import SamplingParams, probs, sample
from repro.engine.scheduler import chunk_prefill_substep
from repro.models.lm import Model, cow_copy_blocks
from repro.telemetry.counters import bump


# ---------------------------------------------------------------------------
# Acceptance rules (pure, unit-testable)
# ---------------------------------------------------------------------------

def greedy_accept(drafts: jnp.ndarray, p_logits: jnp.ndarray, depth=None):
    """Greedy prefix acceptance.

    ``drafts`` [B, n] proposed tokens; ``p_logits`` [B, n+1, V] verifier
    logits (row ``i`` scores proposal ``i``; row ``n`` is the bonus
    position).  Returns ``(out [B, n+1], n_acc [B])``: rows ``< n_acc`` of
    ``out`` are the accepted drafts, row ``n_acc`` the verifier's own
    argmax (the correction after a mismatch, or the bonus token when all
    drafts matched); rows past that are don't-care.

    ``depth`` (traced scalar or per-slot [B]) caps the accepted prefix:
    positions at or beyond it count as mismatches, so the round behaves
    exactly like a static ``n_spec = depth`` round (the correction at
    position ``depth`` is the verifier argmax after ``depth`` accepted
    drafts — the bonus token).
    """
    B, n1 = p_logits.shape[:2]
    n = n1 - 1
    tgt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)       # [B, n+1]
    match = tgt[:, :n] == drafts
    if depth is not None:
        match = match & (jnp.arange(n)[None, :]
                         < jnp.reshape(depth, (-1, 1)))
    match = match.astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)             # [B] 0..n
    out = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    fix = jnp.take_along_axis(tgt, a[:, None], axis=1)[:, 0]
    return out.at[jnp.arange(B), a].set(fix), a


def rejection_accept(key, drafts: jnp.ndarray, q_logits: jnp.ndarray,
                     p_logits: jnp.ndarray, sp: SamplingParams, depth=None):
    """Lossless speculative rejection sampling (Leviathan et al.) over the
    **warped** draft/target distributions.

    ``drafts`` [B, n] were sampled from ``probs(q_logits, sp)``; draft ``i``
    is accepted with probability ``min(1, p_i(d_i) / q_i(d_i))``, the first
    rejection is resampled from ``norm(max(p_i - q_i, 0))``, and the
    all-accepted case draws the bonus token from ``p_{n+1}`` (the same
    formula with ``q := 0``).  The emitted-token distribution equals plain
    sampling from the warped target — pinned by a frequency test.

    ``depth`` caps the proposal: positions at or beyond it are rejected
    outright AND their ``q`` is zeroed, so when the accept chain stops at
    the cutoff the resample draws from ``norm(max(p - 0, 0)) = p`` — the
    bonus formula — and the emitted distribution is identical to a static
    ``n_spec = depth`` round (losslessness is depth-independent).
    Returns ``(out [B, n+1], n_acc [B])`` like :func:`greedy_accept`.
    """
    B, n1, V = p_logits.shape
    n = n1 - 1
    qp = probs(q_logits, sp)                                    # [B, n, V]
    pp = probs(p_logits, sp)                                    # [B, n+1, V]
    pd = jnp.take_along_axis(pp[:, :n], drafts[..., None], axis=-1)[..., 0]
    qd = jnp.take_along_axis(qp, drafts[..., None], axis=-1)[..., 0]
    ku, kr = jax.random.split(key)
    u = jax.random.uniform(ku, (B, n))
    accept = u * qd < pd                        # P[accept] = min(1, p/q)
    if depth is not None:
        live = jnp.arange(n)[None, :] < jnp.reshape(depth, (-1, 1))
        accept = accept & live
        qp = qp * live[..., None].astype(qp.dtype)
    accept = accept.astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(accept, axis=1), axis=1)            # [B] 0..n
    pa = jnp.take_along_axis(pp, a[:, None, None], axis=1)[:, 0]
    q_ext = jnp.concatenate([qp, jnp.zeros((B, 1, V), qp.dtype)], axis=1)
    qa = jnp.take_along_axis(q_ext, a[:, None, None], axis=1)[:, 0]
    r = jnp.maximum(pa - qa, 0.0)
    z = jnp.sum(r, axis=-1, keepdims=True)
    r = jnp.where(z > 0, r / z, pa)             # p == q numerically: use p
    tail = jax.random.categorical(kr, jnp.log(jnp.maximum(r, 1e-38)),
                                  axis=-1).astype(jnp.int32)
    out = jnp.concatenate([drafts, jnp.zeros((B, 1), jnp.int32)], axis=1)
    return out.at[jnp.arange(B), a].set(tail), a


# ---------------------------------------------------------------------------
# Dynamic speculation depth (host-side policy, telemetry-driven)
# ---------------------------------------------------------------------------

@dataclass
class DepthController:
    """AIMD controller for the speculative draft depth.

    The engine feeds it the per-dispatch ``(drafted, accepted)`` deltas of
    the device counter tree; :meth:`update` moves ``depth`` between 1 and
    ``n_max``: additive-increase after ``patience`` consecutive dispatches
    at acceptance rate >= ``hi`` (the draft is earning its forwards —
    speculate deeper), multiplicative-decrease (halve) the moment the rate
    drops below ``lo`` (a misaligned draft burns a draft forward per
    rejected position — collapse toward plain decoding).  Rates in between
    hold depth and reset the streak.

    Depth is a *runtime operand* of the jitted dispatch (the grids stay
    sized for ``n_max``), so every move here is free: zero recompiles,
    pinned by tests and the staticcheck fingerprint manifest.
    """
    n_max: int
    lo: float = 0.45
    hi: float = 0.75
    patience: int = 2
    depth: int = 0          # 0 -> start at n_max (set in __post_init__)
    streak: int = 0

    def __post_init__(self):
        if self.n_max < 1:
            raise ValueError(f"n_max must be >= 1, got {self.n_max}")
        if not self.depth:
            self.depth = self.n_max
        self.depth = max(1, min(self.depth, self.n_max))

    def update(self, drafted: int, accepted: int) -> int:
        """Fold one dispatch's counters in; returns the depth for the next
        dispatch.  Zero-draft dispatches (all slots prefilling) are
        ignored — no evidence, no move."""
        if drafted <= 0:
            return self.depth
        rate = accepted / drafted
        if rate >= self.hi:
            self.streak += 1
            if self.streak >= self.patience:
                self.depth = min(self.n_max, self.depth + 1)
                self.streak = 0
        elif rate < self.lo:
            self.depth = max(1, self.depth // 2)
            self.streak = 0
        else:
            self.streak = 0
        return self.depth


# ---------------------------------------------------------------------------
# The K-round speculative dispatch
# ---------------------------------------------------------------------------

def make_spec_dispatch(model: Model, sp: SamplingParams, k_steps: int,
                       n_spec: int, *, cow: bool = False, chunk: int = 0):
    """Build the jitted K-round speculative dispatch.

    ``dispatch(params, draft_params, state, cache, depth, key)`` ->
    ``(state, cache, tokens [B, K*(n_spec+1)], emitted [B, K*(n_spec+1)])``
    — ``emitted[b]`` marks the tokens slot ``b`` really produced (a
    contiguous prefix per round, rounds concatenated in order, so the host
    appends ``tokens[b, emitted[b]]`` verbatim, exactly like the plain
    dispatch's grid).  The round bumps the device counter tree
    (``state["ctr"]`` — drafted/accepted/rejected, CoW copies, blocked
    retries, block pops/releases), which the host reads in the same sync;
    its per-dispatch drafted/accepted deltas are the acceptance-rate
    telemetry the :class:`DepthController` consumes.  ``depth`` is the
    dynamic speculation depth (a traced ``int32``; pass ``jnp.int32(d)``,
    a weak Python literal would retrace per value).

    ``cow=True`` composes with refcounted prefix caching: the round's span
    allocation copies-on-write a shared first block (see module
    docstring).  ``chunk > 0`` appends the in-scan chunked-prefill phase
    to every round.  The same ``state`` pytree as the plain dispatch is
    used (plus the prefill fields when chunked); blocks of slots that
    drain mid-dispatch are pushed back inside the scan, as in the
    non-speculative path.
    """
    if model.decode_step_paged is None or model.verify_chunk_paged is None:
        raise NotImplementedError(
            f"model family {model.cfg.family!r} has no paged decode/verify "
            f"path")
    if chunk and model.prefill_chunk_paged is None:
        raise NotImplementedError(
            f"model family {model.cfg.family!r} has no chunked-prefill path")
    mcfg = model.cfg
    # SSM state is recurrent and SWA rings are position-keyed: rejected
    # rows cannot be rewound by masking, so those families re-run the
    # verify with valid = accepted over the pre-round cache (pass 2)
    two_pass = mcfg.family in ("ssm", "hybrid") or bool(mcfg.sliding_window)
    S1 = n_spec + 1

    def dispatch(params, draft_params, state, cache, depth, key):
        B = state["active"].shape[0]
        depth = jnp.clip(jnp.asarray(depth, jnp.int32), 1, n_spec)

        def round_body(carry, step_key):
            st, cache = carry
            ctr = st["ctr"]
            active = st["active"]
            lengths = cache["lengths"]
            blocked = jnp.zeros((B,), bool)
            nf_r0 = cache["n_free"]      # pops this round, by free-list delta
            # ---- 1. span allocation + CoW (once per round) --------------
            leaf = next((l for l in cache["stack"].values() if "pk" in l),
                        None)
            if leaf is not None:
                bs = leaf["pk"].shape[2]
                cap = cache["tbl"].shape[1] * bs
                ring = bool(mcfg.sliding_window) and cap == mcfg.sliding_window
                bstate, cow_src, cow_dst, blocked = alloc_span(
                    {k: cache[k] for k in BSTATE_KEYS}, lengths, S1, bs,
                    cap, ring, cow=cow)
                cache = {**cache, **bstate}
                if cow:
                    cache = cow_copy_blocks(cache, cow_src, cow_dst,
                                            jnp.any(cow_src != cow_dst))
                    ctr = bump(ctr, cow_copies=jnp.sum(cow_src != cow_dst))
            # a slot whose shared block could not be CoWed sits the round
            # out entirely (no draft writes, no verify, no emission) and
            # retries next round — unreachable under the reservation
            # ledger, but a draft write into a live shared block would be
            # silent corruption, so the mask is enforced regardless
            active_r = active & ~blocked
            sa = cache["slot_active"]
            # ---- 2. draft (quantized tree, working cache copy) ----------
            def draft_body(dc, dk):
                dcache, cur = dc
                logits, dcache = model.decode_step_paged(draft_params, cur,
                                                         dcache)
                nxt = sample(logits, dk, sp)
                return (dcache, nxt[:, None]), (nxt, logits)

            dkeys = jax.random.split(jax.random.fold_in(step_key, 0), n_spec)
            (dcache, _), (dtoks, dlogits) = jax.lax.scan(
                draft_body, ({**cache, "slot_active": sa & ~blocked},
                             st["cur"]), dkeys)
            drafts = dtoks.T                                    # [B, n]
            # ---- 3. verify (full-precision tree, one forward) -----------
            vtoks = jnp.concatenate([st["cur"], drafts], axis=1)
            vvalid = jnp.where(active_r, S1, 0)
            # one-pass families reuse the draft's cache (its span rows are
            # fully overlaid/overwritten by the verify); two-pass families
            # must keep the pre-round cache for the commit pass.  The
            # blocked-slot mask on slot_active is undone either way.
            vc_in = {**(cache if two_pass else dcache), "lengths": lengths,
                     "slot_active": sa}
            v_logits, vcache = model.verify_chunk_paged(
                params, vtoks, vc_in, lengths, vvalid)
            # ---- 4. accept (depth-masked) -------------------------------
            if sp.greedy:
                out, a = greedy_accept(drafts, v_logits, depth)
            else:
                out, a = rejection_accept(
                    jax.random.fold_in(step_key, 1), drafts,
                    dlogits.transpose(1, 0, 2), v_logits, sp, depth)
            m = jnp.where(active_r, jnp.minimum(a + 1, st["remaining"]), 0)
            # ---- 5. commit + rollback -----------------------------------
            new_len = jnp.where(active_r, lengths + m, lengths)
            if two_pass:
                _, ccache = model.verify_chunk_paged(
                    params, vtoks, {**cache, "lengths": lengths}, lengths,
                    m)
                cache = {**ccache, "lengths": new_len}
            else:
                cache = {**vcache, "lengths": new_len}
            # ---- 6. emit + budget ---------------------------------------
            em = active_r[:, None] & (jnp.arange(S1)[None, :] < m[:, None])
            cur = jnp.take_along_axis(out, jnp.maximum(m - 1, 0)[:, None],
                                      axis=1)
            cur = jnp.where(active_r[:, None], cur, st["cur"])
            remaining = st["remaining"] - m
            new_active = active & (remaining > 0)
            # ---- 7. recycle drained slots' blocks in-scan ---------------
            nf1 = cache["n_free"]
            bstate = release_slots({k: cache[k] for k in BSTATE_KEYS},
                                   active & ~new_active)
            cache = {**cache, **bstate}
            drafted = jnp.sum(jnp.where(active_r, depth, 0))
            accepted = jnp.sum(jnp.where(active_r, a, 0))
            ctr = bump(ctr,
                       tokens=jnp.sum(m),
                       drafted=drafted,
                       accepted=accepted,
                       rejected=drafted - accepted,
                       blocked_retries=jnp.sum(blocked),
                       blocks_popped=nf_r0 - nf1,
                       blocks_released=cache["n_free"] - nf1)
            st = {**st, "cur": cur, "active": new_active,
                  "remaining": remaining, "ctr": ctr}
            out_grid = out
            # ---- 8. chunked-prefill phase -------------------------------
            if chunk:
                st, cache, first, completed = chunk_prefill_substep(
                    model, sp, chunk, params, st, cache,
                    jax.random.fold_in(step_key, 2))
                # a slot completing prefill this round was inactive during
                # the speculative phase, so its grid row is all don't-care:
                # the first token goes in column 0
                col0 = jnp.arange(S1)[None, :] == 0
                hit = completed[:, None] & col0
                out_grid = jnp.where(hit, first[:, None], out)
                em = em | hit
            return (st, cache), (out_grid, em)

        keys = jax.random.split(key, k_steps)
        (state, cache), (toks, em) = jax.lax.scan(
            round_body, (state, cache), keys)
        toks = toks.transpose(1, 0, 2).reshape(B, k_steps * S1)
        em = em.transpose(1, 0, 2).reshape(B, k_steps * S1)
        return state, cache, toks, em

    return dispatch
