# Pallas TPU kernels (validated on CPU via interpret=True):
#   scale_search -- fused DAQ candidate sweep (the paper's Alg. 1 hot-spot)
#   fp8_matmul   -- fused block-dequant matmul (fp8 serving)
#   fp8_quant    -- one-pass block absmax + E4M3 cast
