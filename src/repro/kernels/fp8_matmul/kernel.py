"""Fused block-dequant fp8 matmul kernel (serving hot path).

y = x @ dequant(w_q, scales):  x [M, K] bf16, w_q [K, N] fp8-E4M3 with one
fp32 scale per 128x128 block.  The weight tile is dequantized in VMEM on
its way into the MXU — weight HBM traffic is 1 byte/elem instead of 2
(bf16), which is the bound at decode (weight-bandwidth-limited), so the
roofline win is ~2x decode throughput.

Tiling: grid (M/bm, N/bn, K/bk) with bk = bn = 128 (the quant block edge),
so each weight tile has exactly one scale.  fp32 accumulation happens in
the output block, which is revisited across the innermost K grid axis (the
standard Pallas revisiting pattern); the bf16 cast is the wrapper's final
epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, wq_ref, scale_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = wq_ref[...].astype(jnp.float32) * scale_ref[0, 0]
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def matmul_fp8_pallas(x: jnp.ndarray, wq: jnp.ndarray, scales: jnp.ndarray,
                      *, bm: int = 128, block: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """x [M, K]; wq [K, N] fp8; scales [K/block, N/block] fp32.
    Returns fp32 [M, N] (caller casts)."""
    M, K = x.shape
    N = wq.shape[1]
    bm = min(bm, M)
    n_m, n_n, n_k = M // bm, N // block, K // block
    return pl.pallas_call(
        _matmul_kernel,
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((bm, block), lambda m, n, k: (m, k)),
            pl.BlockSpec((block, block), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, block), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, wq, scales)
