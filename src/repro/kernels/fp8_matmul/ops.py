"""Jitted wrapper for the fused fp8 dequant-matmul.

``matmul_fp8(x, qt)`` consumes a QuantizedTensor (block granularity) and
handles: leading batch dims on x, padding to tile multiples, and the bf16
epilogue cast.  CPU runs interpret mode; on TPU flip ``interpret=False``
(the USE_KERNELS switch in quant_runtime/qlinear.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fp8_matmul.kernel import matmul_fp8_pallas


@partial(jax.jit, static_argnames=("block", "interpret"))
def matmul_fp8_2d(x, wq, scales, *, block: int = 128,
                  interpret: bool = True):
    M, K = x.shape
    N = wq.shape[1]
    pm = (-M) % min(128, max(M, 8))
    pk = (-K) % block
    pn = (-N) % block
    if pk or pn:
        raise ValueError("fp8 weights must be padded to the quant block")
    xp = jnp.pad(x, ((0, pm), (0, 0))) if pm else x
    out = matmul_fp8_pallas(xp, wq, scales, bm=min(128, xp.shape[0]),
                            block=block, interpret=interpret)
    return out[:M]


def matmul_fp8(x: jnp.ndarray, qt, *, interpret: bool = True) -> jnp.ndarray:
    """x [..., K] @ QuantizedTensor(block) -> [..., N] in x.dtype."""
    scales = qt.scale
    if scales.ndim == 4:      # [K/bs, 1, N/bs, 1] broadcast layout
        scales = scales[:, 0, :, 0]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = matmul_fp8_2d(x2, qt.data, scales, block=qt.block_size,
                        interpret=interpret)
    return out.reshape(*lead, out.shape[-1]).astype(x.dtype)
