"""Pure-jnp oracle for the fused block-dequant fp8 matmul."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_fp8_ref(x: jnp.ndarray, wq: jnp.ndarray, scales: jnp.ndarray,
                   *, block: int = 128) -> jnp.ndarray:
    """x [M, K]; wq [K, N] fp8; scales [K/block, N/block]. fp32 out."""
    K, N = wq.shape
    nk, nn = K // block, N // block
    w = wq.astype(jnp.float32).reshape(nk, block, nn, block)
    w = w * scales[:, None, :, None]
    w = w.reshape(K, N)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32)
