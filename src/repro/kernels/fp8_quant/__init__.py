from repro.kernels.fp8_quant import ops, ref
