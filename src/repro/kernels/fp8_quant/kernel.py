"""One-pass block absmax + fp8 cast kernel (runtime (re)quantization).

Computes, per 128x128 block, the AbsMax scale s0 = max|W|/448 and the
saturating E4M3 cast — one HBM read of W, one fp8 write + scale write,
instead of the two-pass (absmax pass, then quantize pass) jnp formulation.
Used by the serving path when re-quantizing updated adapters and by the
alpha != 1 DAQ finalization (scale = alpha * s0 folded in via ``alpha``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(w_ref, alpha_ref, q_ref, s_ref, *, qmax: float):
    w = w_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(w))
    s0 = jnp.maximum(amax, 1e-12) / qmax
    scale = alpha_ref[0] * s0
    q = jnp.clip(w / scale, -qmax, qmax).astype(jnp.float8_e4m3fn)
    q_ref[...] = q
    s_ref[0, 0] = scale


def quantize_fp8_pallas(w: jnp.ndarray, alpha: jnp.ndarray, *,
                        block: int = 128, qmax: float = 448.0,
                        interpret: bool = True):
    """w [I, O] (block multiples); alpha scalar [1].  Returns
    (q [I, O] fp8, scales [I/b, O/b] fp32)."""
    I, O = w.shape
    nbi, nbo = I // block, O // block
    kernel = functools.partial(_quant_kernel, qmax=qmax)
    return pl.pallas_call(
        kernel,
        grid=(nbi, nbo),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((I, O), jnp.float8_e4m3fn),
            jax.ShapeDtypeStruct((nbi, nbo), jnp.float32),
        ],
        interpret=interpret,
    )(w, alpha)
