"""Jitted wrapper for the fp8 block-quantize kernel (pads ragged edges)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.granularity import pad_to_blocks
from repro.kernels.fp8_quant.kernel import quantize_fp8_pallas


@partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_fp8(w: jnp.ndarray, alpha: float | jnp.ndarray = 1.0, *,
                 block: int = 128, interpret: bool = True):
    """w [I, O] -> (q [I, O] fp8 (unpadded layout), scales [ceil(I/b), ceil(O/b)])."""
    I, O = w.shape
    wp, _ = pad_to_blocks(w.astype(jnp.float32), block)
    a = jnp.asarray(alpha, jnp.float32).reshape(1)
    q, s = quantize_fp8_pallas(wp, a, block=block, interpret=interpret)
    return q[:I, :O], s
