"""Pure-jnp oracle for the fp8 block-quantize kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_fp8_ref(w: jnp.ndarray, alpha: jnp.ndarray, *,
                     block: int = 128, qmax: float = 448.0):
    I, O = w.shape
    nbi, nbo = I // block, O // block
    wb = w.astype(jnp.float32).reshape(nbi, block, nbo, block)
    amax = jnp.max(jnp.abs(wb), axis=(1, 3))
    s0 = jnp.maximum(amax, 1e-12) / qmax
    scale = alpha[0] * s0
    q = jnp.clip(wb / scale[:, None, :, None], -qmax, qmax)
    q = q.astype(jnp.float8_e4m3fn).reshape(I, O)
    return q, scale
