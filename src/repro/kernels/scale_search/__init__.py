from repro.kernels.scale_search import ops, ref
from repro.kernels.scale_search.kernel import sweep_partials_pallas
