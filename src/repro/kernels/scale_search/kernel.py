"""Fused DAQ scale-search sweep kernel (the paper's compute hot-spot).

Algorithm 1 evaluates ~16 candidate scales per tensor; a naive
implementation re-reads ``W_post``/``W_base`` from HBM for every candidate
(>=16 full passes per stage).  This kernel loads each 128x128 weight block
into VMEM **once** and evaluates ALL candidates against the resident tile,
accumulating the five DAQ partial sums per (candidate, block):

  [sq_err, n_sign_match, dot(dp,dq), |dp|^2, |dq|^2]  (+3 pad lanes)

Predicted effect (napkin): the search becomes 1 HBM pass instead of ~16 —
an ~8x reduction of the search's memory roofline term per stage; measured
in benchmarks/bench_search.py and EXPERIMENTS.md §Perf.

Tiling: grid over (I/bs, O/bs) blocks; the candidate loop is unrolled over
the VMEM-resident tile (n_cand * 2 tile-sized fp32 temporaries stay in
registers/VMEM: 16 candidates x 2 x 64 KiB = 2 MiB << 128 MiB v5e VMEM...
at bs=128 a tile is 128*128*4 B = 64 KiB; wp/wb + accumulators fit easily).
The fp8 quantize-dequantize runs on the VPU (convert + clip); the dot
products run as elementwise multiplies + reductions.

Outputs: partials [n_cand, I/bs, O/bs, 8] fp32 (last dim padded to 8 for
lane friendliness; slots 5..7 are zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_STATS = 8  # 5 used + 3 pad


def _qdq_e4m3(w, scale, qmax: float):
    scaled = w / scale
    clipped = jnp.clip(scaled, -qmax, qmax)
    q = clipped.astype(jnp.float8_e4m3fn)
    return q.astype(jnp.float32) * scale


def _sweep_kernel(wp_ref, wb_ref, s0_ref, alphas_ref, out_ref, *,
                  n_cand: int, qmax: float):
    wp = wp_ref[...].astype(jnp.float32)
    wb = wb_ref[...].astype(jnp.float32)
    s0 = s0_ref[0, 0]
    dp = wp - wb
    sign_dp = jnp.sign(dp)
    dp_sq = jnp.sum(dp * dp)
    for c in range(n_cand):  # unrolled: tile stays VMEM-resident
        alpha = alphas_ref[c]
        wq = _qdq_e4m3(wp, alpha * s0, qmax)
        dq = wq - wb
        diff = dq - dp
        stats = jnp.stack([
            jnp.sum(diff * diff),                                # sq_err
            jnp.sum((sign_dp == jnp.sign(dq)).astype(jnp.float32)),
            jnp.sum(dp * dq),                                    # dot
            dp_sq,
            jnp.sum(dq * dq),
            jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0),
        ])
        out_ref[c, 0, 0, :] = stats


def sweep_partials_pallas(wp: jnp.ndarray, wb: jnp.ndarray,
                          s0: jnp.ndarray, alphas: jnp.ndarray, *,
                          block_size: int = 128, qmax: float = 448.0,
                          interpret: bool = True) -> jnp.ndarray:
    """wp/wb [I, O] (pre-padded to block multiples), s0 [I/bs, O/bs],
    alphas [n_cand].  Returns partials [n_cand, I/bs, O/bs, 8] fp32."""
    I, O = wp.shape
    bs = block_size
    nbi, nbo = I // bs, O // bs
    n_cand = alphas.shape[0]

    kernel = functools.partial(_sweep_kernel, n_cand=n_cand, qmax=qmax)
    return pl.pallas_call(
        kernel,
        grid=(nbi, nbo),
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
            pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((n_cand,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((n_cand, 1, 1, N_STATS),
                               lambda i, j: (0, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cand, nbi, nbo, N_STATS),
                                       jnp.float32),
        interpret=interpret,
    )(wp, wb, s0, alphas)
