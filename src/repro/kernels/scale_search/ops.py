"""Jitted wrapper: DAQ coarse/fine candidate sweep over one weight tensor.

``sweep(wp, wb, alphas, qcfg-ish args)`` pads to the block grid, runs the
fused kernel (interpret=True on CPU — the TPU path flips the flag), and
reduces the per-block partials to the per-candidate / per-block objective
values the search needs.  Slot layout matches core.metrics.partial_sums.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.granularity import pad_to_blocks
from repro.kernels.scale_search.kernel import sweep_partials_pallas
from repro.kernels.scale_search.ref import sweep_partials_ref

EPS = 1e-12


@partial(jax.jit, static_argnames=("block_size", "qmax", "use_kernel",
                                   "interpret"))
def sweep(wp: jnp.ndarray, wb: jnp.ndarray, alphas: jnp.ndarray, *,
          block_size: int = 128, qmax: float = 448.0,
          use_kernel: bool = True, interpret: bool = True) -> dict:
    """Returns dict of [n_cand] tensor-level partials + [n_cand, nbi, nbo]
    block-level partials for per-block alpha selection."""
    wp32 = wp.astype(jnp.float32)
    wb32 = wb.astype(jnp.float32)
    wp_p, orig = pad_to_blocks(wp32, block_size)
    wb_p, _ = pad_to_blocks(wb32, block_size)
    nbi, nbo = wp_p.shape[0] // block_size, wp_p.shape[1] // block_size
    amax = jnp.max(jnp.abs(wp_p.reshape(nbi, block_size, nbo, block_size)),
                   axis=(1, 3))
    s0 = jnp.maximum(amax, EPS) / qmax
    fn = sweep_partials_pallas if use_kernel else \
        lambda *a, **k: sweep_partials_ref(*a, **{kk: vv for kk, vv in
                                                  k.items()
                                                  if kk != "interpret"})
    parts = fn(wp_p, wb_p, s0, alphas.astype(jnp.float32),
               block_size=block_size, qmax=qmax, interpret=interpret) \
        if use_kernel else sweep_partials_ref(
            wp_p, wb_p, s0, alphas.astype(jnp.float32),
            block_size=block_size, qmax=qmax)

    # [n_cand, nbi, nbo, 8] -> block + tensor reductions
    block = {
        "sq_err": parts[..., 0], "n_sign_match": parts[..., 1],
        "dot": parts[..., 2], "dp_sq": parts[..., 3], "dq_sq": parts[..., 4],
    }
    tensor = {k: jnp.sum(v, axis=(1, 2)) for k, v in block.items()}
    n = wp.shape[0] * wp.shape[1]  # padding contributes zeros to sums; the
    # sign-match count over padding is a constant (sign(0)==sign(0)) per
    # block — subtract it exactly:
    pad_elems = wp_p.size - n
    tensor["n_sign_match"] = tensor["n_sign_match"] - pad_elems
    tensor["count"] = jnp.full(alphas.shape, float(n), jnp.float32)
    return {"tensor": tensor, "block": block, "s0": s0, "grid": (nbi, nbo)}


def objective_values(parts: dict, metric: str,
                     hybrid_lambda: float = 0.5) -> jnp.ndarray:
    """[n_cand] objective values from sweep() tensor partials."""
    t = parts["tensor"]
    n = jnp.maximum(t["count"], 1.0)
    if metric == "mse":
        return -t["sq_err"] / n
    if metric == "sign":
        return t["n_sign_match"] / n
    cos = t["dot"] / jnp.maximum(
        jnp.sqrt(t["dp_sq"]) * jnp.sqrt(t["dq_sq"]), EPS)
    if metric == "cosine":
        return cos
    if metric == "hybrid":
        return hybrid_lambda * t["n_sign_match"] / n + (1 - hybrid_lambda) * cos
    raise ValueError(metric)
