"""Pure-jnp oracle for the scale-search sweep kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.scale_search.kernel import N_STATS


def _qdq_e4m3(w, scale, qmax: float = 448.0):
    scaled = w / scale
    clipped = jnp.clip(scaled, -qmax, qmax)
    return clipped.astype(jnp.float8_e4m3fn).astype(jnp.float32) * scale


def sweep_partials_ref(wp: jnp.ndarray, wb: jnp.ndarray, s0: jnp.ndarray,
                       alphas: jnp.ndarray, *, block_size: int = 128,
                       qmax: float = 448.0) -> jnp.ndarray:
    """Same contract as kernel.sweep_partials_pallas, via plain jnp."""
    I, O = wp.shape
    bs = block_size
    nbi, nbo = I // bs, O // bs
    wp32 = wp.astype(jnp.float32).reshape(nbi, bs, nbo, bs)
    wb32 = wb.astype(jnp.float32).reshape(nbi, bs, nbo, bs)
    dp = wp32 - wb32

    def per_cand(alpha):
        scale = (alpha * s0)[:, None, :, None]
        wq = _qdq_e4m3(wp32, scale, qmax)
        dq = wq - wb32
        diff = dq - dp
        red = lambda x: jnp.sum(x, axis=(1, 3))
        stats = jnp.stack([
            red(diff * diff),
            red((jnp.sign(dp) == jnp.sign(dq)).astype(jnp.float32)),
            red(dp * dq),
            red(dp * dp),
            red(dq * dq),
            jnp.zeros((nbi, nbo)), jnp.zeros((nbi, nbo)),
            jnp.zeros((nbi, nbo)),
        ], axis=-1)                                   # [nbi, nbo, 8]
        return stats

    return jax.vmap(per_cand)(alphas)                 # [n_cand, nbi, nbo, 8]
