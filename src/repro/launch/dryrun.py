import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell this lowers + compiles the real
step function — ``train_step`` for train shapes, ``prefill`` for
inference-prefill, ``serve_step`` (one token against a seq_len KV cache) for
decode shapes — against the production mesh:

  single-pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips

and records ``memory_analysis()`` (proves fit), ``cost_analysis()`` (FLOPs /
bytes for §Roofline) and the per-device collective traffic parsed from the
partitioned HLO.  Results land in ``experiments/dryrun/*.json`` and feed
``benchmarks/roofline_report.py``.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run wants 512 placeholder
CPU devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze, dominant_ops
from repro.analysis.roofline import model_flops_estimate, roofline_from_costs
from repro.configs import (ASSIGNED, LM_SHAPES, TrainConfig, get_arch,
                           get_shape, shape_applicable)
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import build_model

OUT_DIR = "experiments/dryrun"


def dryrun_train_cfg(cfg) -> TrainConfig:
    """Per-arch train settings for the production dry-run.

    Trillion-scale MoE (kimi-k2, deepseek-v3) needs int8 optimizer moments
    and gradient microbatching to fit the v5e HBM budget — documented in
    EXPERIMENTS.md §Dry-run."""
    big_moe = cfg.name in ("kimi-k2-1t-a32b", "deepseek-v3")
    return TrainConfig(
        remat="full",
        opt_state_dtype="int8" if big_moe else "float32",
        microbatch=4 if big_moe else 0,
    )


def _cell_path(arch: str, shape: str, multi_pod: bool, out_dir: str,
               quantized: bool = False, kv_dtype: str = "bfloat16") -> str:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    qtag = "__fp8w" if quantized else ""
    ktag = "__fp8kv" if kv_dtype != "bfloat16" else ""
    return os.path.join(out_dir,
                        f"{arch}__{shape}__{mesh_tag}{qtag}{ktag}.json")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = OUT_DIR, force: bool = False,
             tc: TrainConfig | None = None, quantized: bool = False,
             kv_dtype: str = "bfloat16") -> dict:
    from repro.runtime import flags
    flags["kv_cache_dtype"] = kv_dtype
    os.makedirs(out_dir, exist_ok=True)
    path = _cell_path(arch, shape_name, multi_pod, out_dir, quantized,
                      kv_dtype)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    result: dict = {"arch": arch, "shape": shape_name,
                    "multi_pod": multi_pod, "mode": shape.mode}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.update({"status": "skipped", "reason": why})
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        model = build_model(cfg)
        tc = tc or dryrun_train_cfg(cfg)

        with jax.set_mesh(mesh):
            # inside the mesh context: cache layouts (GQA repeat-sharding)
            # depend on the ambient mesh at trace time
            specs = input_specs(cfg, shape, model, tc)
            if shape.mode == "train":
                state, batch = specs["state"], specs["batch"]
                st_sh = {
                    "params": SH.params_shardings(state["params"], cfg, mesh),
                    "opt": SH.opt_state_shardings(state["opt"],
                                                  state["params"], cfg, mesh),
                }
                if "err" in state:
                    st_sh["err"] = SH.params_shardings(state["err"], cfg, mesh)
                b_sh = SH.batch_shardings(batch, mesh)
                step = make_train_step(model, tc)
                jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                                 out_shardings=(st_sh, None),
                                 donate_argnums=0)
                lowered = jitted.lower(state, batch)
                params_tree = state["params"]
            elif shape.mode == "prefill":
                params, batch = specs["params"], specs["batch"]
                p_sh = SH.params_shardings(params, cfg, mesh)
                b_sh = SH.batch_shardings(batch, mesh)
                step = make_prefill_step(model, cache_len=shape.seq_len)
                jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
                lowered = jitted.lower(params, batch)
                params_tree = params
            else:  # decode
                params, tokens, cache = (specs["params"], specs["tokens"],
                                         specs["cache"])
                if quantized:  # fp8 DAQ weights (the paper's deployment)
                    from repro.configs import QuantConfig
                    from repro.launch.specs import quantized_param_specs
                    params = quantized_param_specs(params, QuantConfig())
                p_sh = SH.params_shardings(params, cfg, mesh)
                c_sh = SH.cache_shardings(cache, cfg, mesh)
                t_sh = SH.batch_shardings({"tokens": tokens}, mesh)["tokens"]
                step = make_serve_step(model)
                jitted = jax.jit(step,
                                 in_shardings=(p_sh, t_sh, c_sh),
                                 out_shardings=(None, None, c_sh),
                                 donate_argnums=2)
                lowered = jitted.lower(params, tokens, cache)
                params_tree = params
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis()
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        hlo = analyze(txt, n_chips)       # trip-count-aware (analysis/hlo.py)
        colls = hlo["collectives"]
        mflops = model_flops_estimate(cfg, params_tree, shape,
                                      mode=shape.mode)
        rl = roofline_from_costs(
            hlo["flops"], hlo["bytes"],
            float(colls["bytes"].get("total", 0.0)),
            mflops, n_chips)

        result.update({
            "status": "ok",
            "mesh": mesh_info(mesh),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": ma.peak_memory_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            "fits_16g": bool(ma.argument_size_in_bytes - ma.alias_size_in_bytes
                             + ma.temp_size_in_bytes < 16 * 2 ** 30),
            "cost": {"flops_per_chip": hlo["flops"],
                     "bytes_per_chip": hlo["bytes"],
                     "xla_flops_no_trip": float(ca.get("flops", 0.0)),
                     "xla_bytes_no_trip": float(ca.get("bytes accessed", 0.0))},
            "collectives": colls,
            "model_flops": mflops,
            "roofline": rl.row(),
            "dominant_tensors": dominant_ops(txt, top=6),
            "train_cfg": dataclasses.asdict(tc) if shape.mode == "train" else None,
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]})

    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def _fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['reason'][:40]}...)")
    if r["status"] == "error":
        return f"{r['arch']:22s} {r['shape']:12s} ERROR {r['error'][:60]}"
    rl = r["roofline"]
    mem = r["memory"]["peak_bytes"] / 2 ** 30
    return (f"{r['arch']:22s} {r['shape']:12s} ok "
            f"c={rl['compute_s']*1e3:8.2f}ms m={rl['memory_s']*1e3:8.2f}ms "
            f"coll={rl['collective_s']*1e3:8.2f}ms dom={rl['dominant']:10s} "
            f"peak={mem:6.2f}GiB mfu<={rl['mfu_bound']*100:5.1f}% "
            f"compile={r['compile_s']:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quantized", action="store_true",
                    help="decode cells: fp8 DAQ weights (QuantizedTensor)")
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=["bfloat16", "float8_e4m3fn"],
                    help="KV-cache storage dtype (fp8 halves cache traffic)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells: list[tuple[str, str]] = []
    if args.all:
        for cfg in ASSIGNED:
            for s in LM_SHAPES:
                cells.append((cfg.name, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    n_bad = 0
    for mp in meshes:
        for arch, shape in cells:
            r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         force=args.force, quantized=args.quantized,
                         kv_dtype=args.kv_dtype)
            print(("[2pod] " if mp else "[1pod] ") + _fmt_row(r), flush=True)
            n_bad += r["status"] == "error"
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
