"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and tests/benches must keep seeing 1 device.

Axis semantics:
  pod    -- spans ICI-disconnected pods (DCN); pure data parallelism.
  data   -- intra-pod data parallel + FSDP (ZeRO-3 parameter sharding).
  model  -- tensor/expert parallel.
"""
from __future__ import annotations

import jax
import numpy as np


def _auto(n: int) -> dict:
    """axis_types kwargs when this jax exposes them (explicit-sharding era);
    older jax (< 0.5) predates AxisType and defaults every axis to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def use_mesh(mesh):
    """Context manager activating ``mesh`` for jitted code, tolerant of the
    API churn across jax versions: ``jax.set_mesh`` (explicit-sharding era),
    ``jax.sharding.use_mesh`` (transition releases), or the Mesh's own
    context manager (jax <= 0.4.x)."""
    setter = (getattr(jax, "set_mesh", None)
              or getattr(jax.sharding, "use_mesh", None))
    if setter is not None:
        return setter(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto(len(axes)))


def make_host_mesh(*, model: int = 1):
    """A mesh over whatever devices exist (tests, CPU examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **_auto(2))


def mesh_info(mesh) -> dict:
    return {
        "axis_names": list(mesh.axis_names),
        "shape": [int(mesh.devices.shape[i]) for i in range(mesh.devices.ndim)],
        "n_devices": int(np.prod(mesh.devices.shape)),
    }


def dp_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch (pod is an outer data axis)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape["model"]
