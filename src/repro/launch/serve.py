"""Batched serving driver: prefill + decode with (optionally fp8) weights.

A deliberately small but real serving loop:

* **Slot-based continuous batching (lite)** — a fixed pool of B slots, each
  holding one request's state (length, remaining tokens).  When a request
  finishes, the next queued request is prefilled into the freed slot while
  the other slots keep decoding — the standard continuous-batching pattern
  reduced to slot granularity.  Per-slot lengths ride the cache's
  ``lengths`` vector, so mixed-progress batches are exact.
* **Quantized weights** — pass ``--daq`` to serve fp8 weights quantized
  through ``repro.quantize`` (method selectable via ``--method``): the
  parameter tree's matmul leaves become QuantizedTensor nodes and the same
  model code serves them (quant_runtime/qlinear.py); on TPU the fused
  dequant-matmul Pallas kernel takes over (kernels/fp8_matmul).
  Delta-aware methods want a real base model — point ``--base-ckpt`` at a
  checkpoint directory (e.g. ``experiments/study/base``); without it a
  jittered copy stands in (with a loud warning — demo only).

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
      --requests 6 --batch 2 --prompt-len 16 --gen 8 \
      [--daq [--method daq] [--base-ckpt experiments/study/base]]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import QuantConfig, get_arch, reduced as reduce_cfg
from repro.data import LanguageSpec, sample_batch
from repro.launch.steps import make_serve_step
from repro.models import build_model


def single_slot_prefill(model, params, cache, tokens_row, slot: int,
                        cache_len: int):
    """Prefill one request into ``slot`` of a live batch cache.

    Runs a batch-1 prefill and scatters the resulting per-layer cache rows
    into the slot (the per-slot path of continuous batching)."""
    logits, one_cache = model.prefill(
        params, {"tokens": tokens_row[None]}, cache_len=cache_len)

    # scatter every [n_periods, 1, ...] leaf into [n_periods, B, ...] slot
    def scatter(full_leaf, one_leaf):
        return full_leaf.at[:, slot].set(one_leaf[:, 0].astype(full_leaf.dtype))

    new_stack = jax.tree.map(scatter, cache["stack"], one_cache["stack"])
    new_cache = dict(cache)
    new_cache["stack"] = new_stack
    if "prefix" in cache:
        new_cache["prefix"] = jax.tree.map(scatter, cache["prefix"],
                                           one_cache["prefix"])
    new_cache["lengths"] = cache["lengths"].at[slot].set(
        one_cache["lengths"][0])
    return logits[0], new_cache


def serve(model, params, requests: list[jnp.ndarray], *, batch: int,
          gen_tokens: int, cache_len: int, greedy: bool = True) -> list[list[int]]:
    """Serve ``requests`` (token arrays) with a B-slot continuous batcher."""
    cfg = model.cfg
    serve_step = jax.jit(make_serve_step(model), donate_argnums=2)
    cache = model.init_cache(batch, cache_len)
    cur = jnp.zeros((batch, 1), jnp.int32)
    active = [-1] * batch                 # request id per slot
    remaining = [0] * batch
    outputs: dict[int, list[int]] = {}
    queue = list(range(len(requests)))

    def fill_slot(slot, cache, cur):
        rid = queue.pop(0)
        logits, cache = single_slot_prefill(model, params, cache,
                                            requests[rid], slot, cache_len)
        nxt = int(jnp.argmax(logits)) if greedy else int(logits.argmax())
        cur = cur.at[slot, 0].set(nxt)
        outputs[rid] = [nxt]
        active[slot] = rid
        remaining[slot] = gen_tokens - 1
        return cache, cur

    for slot in range(batch):
        if queue:
            cache, cur = fill_slot(slot, cache, cur)

    while any(a >= 0 for a in active):
        cur, logits, cache = serve_step(params, cur, cache)
        for slot in range(batch):
            rid = active[slot]
            if rid < 0:
                continue
            outputs[rid].append(int(cur[slot, 0]))
            remaining[slot] -= 1
            if remaining[slot] <= 0:
                active[slot] = -1
                if queue:
                    cache, cur = fill_slot(slot, cache, cur)
    return [outputs[i] for i in sorted(outputs)]


def _load_base_params(base_ckpt: str, params):
    """Base tree for delta-aware quantization.

    With ``--base-ckpt``: restore the real base model via repro.checkpoint
    (accepts either a bare params tree or a train-state checkpoint with a
    ``params`` sub-tree).  Without: fall back to a jittered copy of the
    serving weights — delta metrics are then meaningless, so warn loudly.
    """
    if base_ckpt:
        from repro import checkpoint as ckpt
        step = ckpt.latest(base_ckpt)
        if step is None:
            raise SystemExit(f"--base-ckpt {base_ckpt}: no checkpoint found")
        params_shape = jax.eval_shape(lambda: params)
        # the manifest tells the layout apart: train-state checkpoints nest
        # leaves under "params.", bare params trees don't — so a genuine
        # restore failure (e.g. arch/shape mismatch) propagates as itself
        leaves = ckpt.meta(base_ckpt, step)["leaves"]
        if any(name.startswith("params.") for name in leaves):
            return ckpt.restore(base_ckpt, step,
                                {"params": params_shape})["params"]
        return ckpt.restore(base_ckpt, step, params_shape)
    print("[serve] WARNING: no --base-ckpt given; using a jittered copy of "
          "the serving weights as the base model. Delta-aware metrics are "
          "meaningless against a fake base — pass --base-ckpt for real use.",
          flush=True)
    return jax.tree.map(
        lambda p: p - 0.01 * jnp.ones_like(p) * (p.ndim >= 2), params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--daq", action="store_true",
                    help="serve fp8-quantized weights (repro.quantize)")
    ap.add_argument("--metric", default="sign")
    ap.add_argument("--method", default="daq",
                    help="quantization method registry key "
                         "(daq | absmax | daq-per-block | ...)")
    ap.add_argument("--base-ckpt", default="",
                    help="checkpoint dir of the BASE model for delta-aware "
                         "quantization (loaded via repro.checkpoint)")
    args = ap.parse_args()
    if not args.daq and (args.base_ckpt or args.method != "daq"
                         or args.metric != "sign"):
        raise SystemExit("--method/--metric/--base-ckpt configure quantized "
                         "serving and require --daq")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve.py demo drives LM-style archs; "
                         "vlm/encdec need modality inputs (see examples/)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    spec = LanguageSpec(vocab=cfg.vocab_size)
    if args.daq:
        from repro.quantize import quantize
        qcfg = QuantConfig(method=args.method, metric=args.metric,
                           granularity="channel")
        base = _load_base_params(args.base_ckpt, params)
        # model=/spec= feed the calibrate hook of calibration-based
        # methods (smoothquant/awq); data-free methods ignore them
        params, report = quantize(params, base, qcfg, mode="storage",
                                  out_dtype="bfloat16", model=model,
                                  spec=spec)
        print(report.summary())
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1,
                            args.prompt_len)[0] for i in range(args.requests)]
    cache_len = args.prompt_len + args.gen + 8

    t0 = time.time()
    outs = serve(model, params, prompts, batch=args.batch,
                 gen_tokens=args.gen, cache_len=cache_len)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served {args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o}")


if __name__ == "__main__":
    main()
