"""Serving CLI: a thin driver over the device-resident engine.

All serving mechanics live in :mod:`repro.engine` — slot scheduling on
device, K-step decode dispatch (one host sync per K tokens), batched
multi-slot prefill with a single jitted cache scatter, greedy / temperature
/ top-k sampling, and opt-in sharded serving over a host mesh.  This module
only parses flags, builds (and optionally quantizes) the model, and calls
``Engine.serve``.

* **Quantized weights** — pass ``--daq`` to serve fp8 weights quantized
  through ``repro.quantize`` (method selectable via ``--method``): the
  parameter tree's matmul leaves become QuantizedTensor nodes and the same
  model code serves them (quant_runtime/qlinear.py); on TPU the fused
  dequant-matmul Pallas kernel takes over (kernels/fp8_matmul).
  Delta-aware methods want a real base model — point ``--base-ckpt`` at a
  checkpoint directory (e.g. ``experiments/study/base``); without it a
  jittered copy stands in (with a loud warning — demo only).
* **Sharded serving** — ``--mesh N`` builds a host mesh with model-parallel
  size N (``launch/mesh.make_host_mesh``) and places params + cache with
  the ``launch/sharding`` specs; quantized ``wq/data`` / ``wq/scale``
  leaves inherit the dense weight's layout.
* **Speculative decoding** — ``--spec-draft METHOD --n-spec N`` (with
  ``--paged``) quantizes the weights with METHOD and serves them as the
  *draft* model: up to N drafted tokens per round, verified by one forward
  of the full-precision weights (engine/spec.py).  Composes freely with
  ``--prefix-cache`` / ``--chunk-size`` — speculative rounds, CoW prefix
  writes and chunk-prefill pieces are phases of one dispatch — so
  shared-prefix workloads measure draft fidelity too.  The round depth is
  dynamic by default (AIMD on the acceptance rate, 1..N, zero recompiles;
  ``--spec-static`` pins it at N).  Greedy output is token-exact vs
  non-speculative serving; the summary line reports the draft acceptance
  rate — a data-free behavioral-fidelity readout of the quantization
  method.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
      --requests 6 --batch 2 --prompt-len 16 --gen 8 --k-steps 8 \
      [--daq [--method daq] [--base-ckpt experiments/study/base]] \
      [--paged --spec-draft daq --n-spec 4] \
      [--temperature 0.8 --top-k 40 --top-p 0.95] [--mesh 1] \
      [--metrics-out metrics.json --trace-out trace.json]

``--metrics-out`` writes the request-lifecycle metrics snapshot
(``repro.telemetry.metrics/v1`` JSON — TTFT/TPOT/queue-wait percentiles,
acceptance rate, prefix-hit fraction, allocator gauges) and ``--trace-out``
a Chrome/Perfetto trace of the run; the CLI summary is printed from the
same snapshot either way.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import QuantConfig, get_arch, reduced as reduce_cfg
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine, SamplingParams
from repro.models import build_model


def serve(model, params, requests: list[jnp.ndarray], *, batch: int,
          gen_tokens: int, cache_len: int, greedy: bool = True,
          sampling: SamplingParams | None = None, k_steps: int = 8,
          mesh=None, seed: int = 0) -> list[list[int]]:
    """Compat wrapper: serve ``requests`` through a fresh :class:`Engine`.

    Kept so existing callers (tests, examples) of the old host-loop API keep
    working; new code should construct an ``Engine`` directly and reuse it
    across calls.
    """
    sp = sampling or SamplingParams(greedy=greedy)
    eng = Engine(model, params, slots=batch, cache_len=cache_len,
                 k_steps=k_steps, sampling=sp, mesh=mesh)
    return eng.serve(requests, gen_tokens=gen_tokens, seed=seed)


def _load_base_params(base_ckpt: str, params):
    """Base tree for delta-aware quantization.

    With ``--base-ckpt``: restore the real base model via repro.checkpoint
    (accepts either a bare params tree or a train-state checkpoint with a
    ``params`` sub-tree).  Without: fall back to a jittered copy of the
    serving weights — delta metrics are then meaningless, so warn loudly.
    """
    if base_ckpt:
        from repro import checkpoint as ckpt
        step = ckpt.latest(base_ckpt)
        if step is None:
            raise SystemExit(f"--base-ckpt {base_ckpt}: no checkpoint found")
        params_shape = jax.eval_shape(lambda: params)
        # the manifest tells the layout apart: train-state checkpoints nest
        # leaves under "params.", bare params trees don't — so a genuine
        # restore failure (e.g. arch/shape mismatch) propagates as itself
        leaves = ckpt.meta(base_ckpt, step)["leaves"]
        if any(name.startswith("params.") for name in leaves):
            return ckpt.restore(base_ckpt, step,
                                {"params": params_shape})["params"]
        return ckpt.restore(base_ckpt, step, params_shape)
    print("[serve] WARNING: no --base-ckpt given; using a jittered copy of "
          "the serving weights as the base model. Delta-aware metrics are "
          "meaningless against a fake base — pass --base-ckpt for real use.",
          flush=True)
    return jax.tree.map(
        lambda p: p - 0.01 * jnp.ones_like(p) * (p.ndim >= 2), params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--k-steps", type=int, default=8,
                    help="decode steps per device dispatch (1 host sync "
                         "per k-steps tokens)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature; 0 = greedy (default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus (top-p) truncation for sampling "
                         "(1.0 = off)")
    ap.add_argument("--mesh", type=int, default=0, metavar="MP",
                    help="serve sharded over a host mesh with "
                         "model-parallel size MP (0 = unsharded)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: global block pool + per-slot "
                         "block tables (memory proportional to live "
                         "tokens, not slots * cache_len)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="tokens per KV block (with --paged)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool size in blocks; 0 = capacity parity "
                         "with the contiguous cache (with --paged)")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="chunked prefill: prompts stream through the "
                         "decode dispatch in pieces of this many tokens "
                         "instead of stalling decode (with --paged)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prompt-prefix block sharing: matched "
                         "leading blocks are mapped instead of re-prefilled "
                         "and stay cached (LRU) after requests finish "
                         "(with --paged; implies chunked prefill)")
    ap.add_argument("--spec-draft", default="", metavar="METHOD",
                    help="self-speculative decoding: quantize the weights "
                         "with METHOD (repro.quantize registry key, e.g. "
                         "daq | absmax) and use them as the draft model, "
                         "verified by the full-precision weights (requires "
                         "--paged)")
    ap.add_argument("--n-spec", type=int, default=4,
                    help="maximum drafted tokens per speculative round "
                         "(with --spec-draft; must be < --k-steps)")
    ap.add_argument("--spec-static", action="store_true",
                    help="pin the speculation depth at --n-spec instead of "
                         "moving it 1..n-spec from acceptance telemetry "
                         "(engine/spec.DepthController)")
    ap.add_argument("--daq", action="store_true",
                    help="serve fp8-quantized weights (repro.quantize)")
    ap.add_argument("--metric", default="sign")
    ap.add_argument("--method", default=None,
                    help="quantization method registry key for --daq "
                         "serving (daq | absmax | daq-per-block | ...); "
                         "default daq.  The speculative draft's method is "
                         "--spec-draft's value, not this flag")
    ap.add_argument("--base-ckpt", default="",
                    help="checkpoint dir of the BASE model for delta-aware "
                         "quantization (loaded via repro.checkpoint)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the request-lifecycle metrics snapshot "
                         "(repro.telemetry.metrics/v1 JSON: TTFT/TPOT "
                         "percentiles, acceptance rate, prefix-hit "
                         "fraction, allocator gauges) to PATH")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "serve run (admission / dispatch / spec / "
                         "prefill-chunk / eviction tracks) to PATH — open "
                         "in https://ui.perfetto.dev or chrome://tracing")
    args = ap.parse_args()
    if not args.daq and not args.spec_draft \
            and (args.base_ckpt or args.method is not None
                 or args.metric != "sign"):
        raise SystemExit("--method/--metric/--base-ckpt configure quantized "
                         "serving and require --daq (or --spec-draft)")
    if args.spec_draft and args.method is not None:
        raise SystemExit("--method configures --daq serving and is not "
                         "read by the speculative path: the draft's "
                         "quantization method IS --spec-draft's value "
                         f"({args.spec_draft!r}) — drop --method")
    if args.spec_draft and args.daq:
        raise SystemExit("--spec-draft verifies quantized drafts against "
                         "the FULL-precision weights; it cannot combine "
                         "with --daq (which quantizes the served weights)")
    if args.spec_draft and not args.paged:
        raise SystemExit("--spec-draft requires --paged (speculative "
                         "decoding rides the paged engine)")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve CLI drives LM-style archs; "
                         "vlm/encdec need modality inputs (see examples/)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    spec = LanguageSpec(vocab=cfg.vocab_size)
    if args.daq:
        from repro.quantize import quantize
        qcfg = QuantConfig(method=args.method or "daq", metric=args.metric,
                           granularity="channel")
        base = _load_base_params(args.base_ckpt, params)
        # model=/spec= feed the calibrate hook of calibration-based
        # methods (smoothquant/awq); data-free methods ignore them
        params, report = quantize(params, base, qcfg, mode="storage",
                                  out_dtype="bfloat16", model=model,
                                  spec=spec)
        print(report.summary())
    draft_params = None
    if args.spec_draft:
        from repro.quantize import quantize
        qcfg = QuantConfig(method=args.spec_draft, metric=args.metric,
                           granularity="channel")
        base = _load_base_params(args.base_ckpt, params)
        draft_params, report = quantize(params, base, qcfg, mode="storage",
                                        out_dtype="bfloat16", model=model,
                                        spec=spec)
        print(f"[serve] speculative draft ({args.spec_draft}):")
        print(report.summary())
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1,
                            args.prompt_len)[0] for i in range(args.requests)]
    cache_len = args.prompt_len + args.gen + 8

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh, mesh_info
        mesh = make_host_mesh(model=args.mesh)
        print(f"[serve] host mesh: {mesh_info(mesh)}")
    if args.temperature <= 0 and args.top_k == 0 and args.top_p >= 1.0:
        sp = SamplingParams()                        # greedy
    else:  # any flag alone enables sampling (temperature defaults to 1)
        sp = SamplingParams(greedy=False,
                            temperature=args.temperature
                            if args.temperature > 0 else 1.0,
                            top_k=args.top_k, top_p=args.top_p)
    if (args.chunk_size or args.prefix_cache) and not args.paged:
        raise SystemExit("--chunk-size/--prefix-cache require --paged")
    from repro.telemetry import MetricsRegistry, Tracer
    reg = MetricsRegistry()
    tracer = Tracer() if args.trace_out else None
    eng = Engine(model, params, slots=args.batch, cache_len=cache_len,
                 k_steps=args.k_steps, sampling=sp, mesh=mesh,
                 paged=args.paged, block_size=args.block_size,
                 num_blocks=args.num_blocks, chunk_size=args.chunk_size,
                 prefix_cache=args.prefix_cache,
                 n_spec=args.n_spec if args.spec_draft else 0,
                 spec_dynamic=not args.spec_static,
                 draft_params=draft_params, metrics=reg, tracer=tracer)

    t0 = time.time()
    outs, stats = eng.serve(prompts, gen_tokens=args.gen, return_stats=True)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    kind = "paged" if args.paged else "contiguous"
    if args.prefix_cache:
        kind += "+prefix"
    if args.spec_draft:
        kind += f"+spec({args.spec_draft})"
    extra = ""
    if args.paged and (args.chunk_size or args.prefix_cache):
        extra = (f", {stats['prefill_tokens']} prompt tokens prefilled"
                 + (f" ({stats.get('prefix_hits', 0)} prefix-hit)"
                    if args.prefix_cache else ""))
    snap = reg.snapshot()
    # acceptance / depth come from the metrics snapshot (the device
    # counter tree feeds the spec.* gauges); non-spec runs report n/a
    acc = snap["gauges"].get("spec.acceptance_rate")
    depth = snap["gauges"].get("spec.depth")
    extra += (", acceptance: n/a" if acc is None else
              f", draft acceptance {acc:.1%} over "
              f"{stats.get('spec_rounds', 0)} rounds of <={args.n_spec}, "
              f"final depth {depth:.0f}")
    print(f"served {args.requests} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, "
          f"{stats['host_syncs']/max(n_tok, 1):.3f} host syncs/token; "
          f"{stats['dispatches']} dispatches of {args.k_steps} steps, "
          f"{stats['prefill_calls']} prefill calls; {kind} cache, "
          f"{stats['cache_bytes']} cache bytes{extra})")
    print("metrics:")
    print(reg.summary())
    if args.metrics_out:
        reg.save(args.metrics_out)
        print(f"[serve] metrics snapshot ({snap['schema']}) -> "
              f"{args.metrics_out}")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"[serve] perfetto trace ({len(tracer.events)} events) -> "
              f"{args.trace_out}")
    # jit cache size per entry point: dispatch/scatter entries hold at 1 in
    # steady state; the prefill entries compile once per distinct prompt-
    # length bucket.  Anything above that is an avoidable recompile — the
    # signature contracts live in `python -m repro.staticcheck`.
    counts = eng.compile_counts()
    total = sum(c for c in counts.values() if c > 0)
    print(f"compiles: {total} total ("
          + ", ".join(f"{k.lstrip('_')}={v}"
                      for k, v in sorted(counts.items())) + ")")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o}")


if __name__ == "__main__":
    main()
