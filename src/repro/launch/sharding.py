"""Sharding rules: parameter, optimizer-state, batch and cache PartitionSpecs.

Conventions (DESIGN.md §5):

* **TP over ``model``** (Megatron): attention q/k/v out-dim and MLP up/gate
  out-dim are column-parallel; attention out-proj and MLP down-proj are
  row-parallel.  MoE expert stacks shard their expert axis over ``model``
  (EP) when divisible, else fall back to per-expert TP.  Mamba components
  are head-structured (z/x/dt) -> ``model``; head-shared (B/C) -> replicated.
* **FSDP over ``data``** (ZeRO-3): the non-TP matrix dim of every weight is
  sharded over ``data``; GSPMD all-gathers per layer at use and
  reduce-scatters gradients.
* **``pod`` is pure DP**: params replicate across pods (a cross-DCN ZeRO
  would serialize every layer on the slow link); only the gradient
  all-reduce crosses pods.
* KV heads shard over ``model`` only when ``n_kv_heads % model_size == 0``
  (GQA caps KV TP); otherwise k/v projections and the KV cache replicate
  over ``model`` and GSPMD inserts the cheap gathers.

All rules key off the parameter's tree path, so quantized trees
(``.../wq/data``, ``.../wq/scale``) inherit the dense weight's layout.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.policy import path_str
from repro.launch.mesh import dp_axes, model_size

STACK_PREFIXES = ("stack", "prefix", "enc_stack")


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _base_param_spec(parts: list[str], shape: tuple[int, ...],
                     cfg: ModelConfig, mesh) -> P:
    """Spec for the *unstacked* weight (trailing dims of the leaf)."""
    name = parts[-1]
    msz = model_size(mesh)
    kv_tp = cfg.n_kv_heads and cfg.n_kv_heads % msz == 0
    is_moe_expert = "moe" in parts and "shared" not in parts

    if name in ("embed",):
        return P("model", "data")
    if name == "w_head":
        return P("data", "model")
    if name == "wq":
        return P("data", "model")
    if name in ("wk", "wv"):
        return P("data", "model") if kv_tp else P("data", None)
    if name == "wo":
        return P("model", "data")
    if name == "bias_q":
        return P("model")
    if name in ("bias_k", "bias_v"):
        return P("model") if kv_tp else P(None)
    if name in ("w_gate", "w_up"):
        if is_moe_expert:                      # [E, D, F]
            from repro.runtime import flags
            if flags["moe_sharding"] == "ep_data_tp_model":
                return P("data", None, "model")
            if cfg.n_experts % msz == 0:
                return P("model", "data", None)
            return P(None, "data", "model")
        return P("data", "model")              # [D, F]
    if name == "w_down":
        if is_moe_expert:                      # [E, F, D]
            from repro.runtime import flags
            if flags["moe_sharding"] == "ep_data_tp_model":
                return P("data", "model", None)
            if cfg.n_experts % msz == 0:
                return P("model", None, "data")
            return P(None, "model", "data")
        return P("model", "data")              # [F, D]
    if name == "router":
        return P(None, None)
    if name in ("in_z", "in_x"):
        return P("data", "model")
    if name in ("in_bc", "in_dt"):
        return P("data", None)
    if name == "out_proj":
        return P("model", "data")
    if name == "conv_x_w":
        return P(None, "model")
    if name == "conv_x_b":
        return P("model")
    if name == "norm_scale" and "mamba" in parts:
        return P("model")
    # norms, small biases, conv_bc, a_log/dt_bias/d_skip: replicate
    return P(*([None] * len(shape)))


def _fit(spec: tuple, shape: tuple[int, ...], mesh) -> tuple:
    """Drop axis assignments whose size does not divide the dim (jit rejects
    non-divisible input shardings; GQA/vocab oddities fall back to
    replication on that dim)."""
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(entry if dim % n == 0 else None)
    return tuple(out)


def param_spec(path, shape: tuple[int, ...], cfg: ModelConfig, mesh) -> P:
    """Spec for a parameter leaf (handles period stacking and quantized
    storage/scale leaves)."""
    parts = path_str(path).split("/")
    quant_field = None
    if parts[-1] in ("data", "scale"):        # QuantizedTensor fields
        quant_field = parts[-1]
        parts = parts[:-1]
    lead = 1 if parts[0] in STACK_PREFIXES else 0

    if quant_field == "scale":
        # scale shapes: block [.., I/bs, 1, O/bs, 1]; channel [.., 1, O]; tensor []
        w_spec = tuple(_base_param_spec(parts, shape, cfg, mesh))
        body = len(shape) - lead
        if body <= 0:
            return P()
        if body == 4:                          # block granularity
            s = (w_spec[0] if len(w_spec) > 0 else None,
                 None,
                 w_spec[1] if len(w_spec) > 1 else None,
                 None)
        elif body == 2:                        # channel granularity
            s = (None, w_spec[1] if len(w_spec) > 1 else None)
        else:
            s = tuple([None] * body)
        s = _fit(s, shape[lead:], mesh)
        return P(*([None] * lead), *s)

    body_shape = shape[lead:]
    spec = tuple(_base_param_spec(parts, body_shape, cfg, mesh))
    spec = spec + (None,) * (len(body_shape) - len(spec))
    spec = _fit(spec[: len(body_shape)], body_shape, mesh)
    return P(*([None] * lead), *spec)


def params_shardings(params_shape: Any, cfg: ModelConfig, mesh) -> Any:
    """NamedSharding tree matching an (abstract) params tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = [NamedSharding(mesh, param_spec(p, tuple(l.shape), cfg, mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Optimizer-state specs (mirror the param layout)
# ---------------------------------------------------------------------------

def opt_state_shardings(opt_shape: Any, params_shape: Any, cfg: ModelConfig,
                        mesh) -> Any:
    """Shardings for the optimizer state produced by ``init_opt_state``.

    fp32/bf16 moments share the param spec.  int8 moments are blocked along
    the last axis: shape = param.shape[:-1] + (nb, 256); scales
    param.shape[:-1] + (nb, 1) — both inherit the param spec with the last
    axis split (blocks keep the axis sharding, the intra-block dim is
    replicated).
    """
    p_flat, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = {path_str(p): param_spec(p, tuple(l.shape), cfg, mesh)
             for p, l in p_flat}

    def one(path, leaf):
        name = path_str(path)                 # "mu/<param path>/m" etc.
        parts = name.split("/")
        if parts[0] == "step":
            return NamedSharding(mesh, P())
        if parts[0] == "master":              # fp32 master copy: param spec
            pkey = "/".join(parts[1:])
        else:
            pkey = "/".join(parts[1:-1])
        base = specs[pkey]
        p_shape = None
        for pp, ll in p_flat:
            if path_str(pp) == pkey:
                p_shape = tuple(ll.shape)
                break
        if len(leaf.shape) == len(p_shape):       # fp32/bf16 moment
            spec = tuple(base)
        else:                                     # int8 blocked (+1 dim)
            spec = tuple(base) + (None,)
        spec = spec + (None,) * (len(leaf.shape) - len(spec))
        spec = _fit(spec[: len(leaf.shape)], tuple(leaf.shape), mesh)
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def _dp(mesh, batch: int):
    axes = dp_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if batch % n == 0 else None


def batch_shardings(batch_shape: dict, mesh) -> dict:
    """Shard every batch leaf's leading (batch) dim over the dp axes."""
    def one(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        dp = _dp(mesh, b)
        spec = P(dp, *([None] * (leaf.ndim - 1))) if dp else \
            P(*([None] * leaf.ndim))
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: Any, cfg: ModelConfig, mesh) -> Any:
    """Decode-cache layout: [n_periods, B, ...] leaves -> batch over dp,
    KV-head / SSM-head dims over model when divisible."""
    msz = model_size(mesh)
    ssm_tp = cfg.family in ("ssm", "hybrid") and cfg.n_ssm_heads % msz == 0

    def one(path, leaf):
        name = path_str(path).split("/")[-1]
        if name == "lengths":
            dp = _dp(mesh, leaf.shape[0])
            return NamedSharding(mesh, P(dp))
        from repro.engine.paged import BSTATE_KEYS
        if name in BSTATE_KEYS:
            # paged-cache allocator state: tiny int/bool arrays, replicated
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        if name in ("pk", "pv"):              # [n, NB+1, bs, Kv_eff, hd]
            # block pool: same head-axis layout as the dense cache; the
            # block axis is shared by every slot, so it replicates over dp
            kv_tp = leaf.shape[3] % msz == 0
            spec = P(None, None, None, "model" if kv_tp else None, None)
            return NamedSharding(
                mesh, P(*_fit(tuple(spec), tuple(leaf.shape), mesh)))
        dp = _dp(mesh, leaf.shape[1])
        if name in ("k", "v", "mk", "mv"):    # [n, B, S, Kv_eff, hd]
            kv_tp = leaf.shape[3] % msz == 0  # repeat-sharded layout (lm.py)
            spec = P(None, dp, None, "model" if kv_tp else None, None)
        elif name == "h":                      # [n, B, nh, P, N]
            spec = P(None, dp, "model" if ssm_tp else None, None, None)
        elif name == "conv_x":                 # [n, B, K-1, di]
            spec = P(None, dp, None, "model" if ssm_tp else None)
        elif name == "conv_bc":
            spec = P(None, dp, None, None)
        else:
            spec = P(*([None] * leaf.ndim))
        spec = P(*_fit(tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec))),
                       tuple(leaf.shape), mesh))
        return NamedSharding(mesh, spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])
