"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

Everything here is allocation-free: batches are ShapeDtypeStructs, caches
come from ``jax.eval_shape`` over ``model.init_cache``, and the train state
from ``jax.eval_shape`` over ``init_train_state`` — full-size configs are
only ever lowered, never materialized (assignment spec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models.lm import Model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def modality_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    if cfg.family == "vlm":
        return {"image_embeds": sds((batch, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)}
    if cfg.family == "encdec":
        frames = min(seq, cfg.enc_frames_cap)
        return {"frames": sds((batch, frames, cfg.d_model), jnp.bfloat16)}
    return {}


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
            **modality_specs(cfg, B, S)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": sds((B, S), jnp.int32),
            **modality_specs(cfg, B, S)}


def decode_specs(model: Model, shape: ShapeConfig):
    """(tokens_spec, cache_spec) for one serve_step against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: model.init_cache(B, S))
    return sds((B, 1), jnp.int32), cache


def state_specs(model: Model, tc: TrainConfig):
    from repro.launch.steps import init_train_state
    return jax.eval_shape(
        lambda k: init_train_state(model, tc, k), jax.random.PRNGKey(0))


def quantized_param_specs(params_abstract, qcfg) -> dict:
    """Abstract fp8 parameter tree: every quantizable leaf becomes a
    QuantizedTensor ShapeDtypeStruct pair (storage + block scales), exactly
    the layout ``quantize_tree(mode="storage")`` produces — lets the decode
    dry-run lower the quantized serving path at full size with no
    allocation."""
    from repro.core.formats import get_format
    from repro.core.policy import path_str, should_quantize
    from repro.quant_runtime.qparams import QuantizedTensor

    fmt = get_format(qcfg.fmt)
    bs = qcfg.block_size

    def one(path, leaf):
        name = path_str(path)
        if not should_quantize(name, leaf, qcfg.skip_patterns):
            return leaf
        lead, (I, O) = leaf.shape[:-2], leaf.shape[-2:]
        if qcfg.granularity == "block":
            scale_shape = lead + (-(-I // bs), 1, -(-O // bs), 1)
        elif qcfg.granularity == "channel":
            scale_shape = lead + (1, O)
        else:
            scale_shape = lead
        return QuantizedTensor(
            data=sds(leaf.shape, fmt.storage_dtype),
            scale=sds(scale_shape, jnp.float32),
            fmt=qcfg.fmt, granularity=qcfg.granularity, block_size=bs,
            out_dtype="bfloat16")

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abstract)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model,
                tc: TrainConfig | None = None):
    """The assignment-facing entry point: all abstract inputs for a cell.

    Returns a dict with keys depending on shape.mode:
      train   -> {"state": ..., "batch": ...}
      prefill -> {"params": ..., "batch": ...}
      decode  -> {"params": ..., "tokens": ..., "cache": ...}
    """
    tc = tc or TrainConfig()
    if shape.mode == "train":
        return {"state": state_specs(model, tc),
                "batch": train_batch_specs(cfg, shape)}
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.mode == "prefill":
        return {"params": params, "batch": prefill_batch_specs(cfg, shape)}
    tokens, cache = decode_specs(model, shape)
    return {"params": params, "tokens": tokens, "cache": cache}
