"""Jittable train / serve steps.

``make_train_step`` builds the full training step: loss + grad (with remat
policy), optional microbatch gradient accumulation (``lax.scan`` over
micro-slices — memory scales with the micro batch, FLOPs unchanged),
optional int8 error-feedback gradient compression, AdamW update.  Gradients
reduce across data/pod axes implicitly through GSPMD (batch is dp-sharded,
params are FSDP-sharded -> grads reduce-scatter back to the param layout).

``make_prefill_step`` builds the prefill step the dry-run cells lower;
``make_serve_step`` is a deprecated greedy shim over the serving engine's
decode step (``repro.engine``).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.lm import Model
from repro.optim import (adamw_update, compress_grads, init_error_state,
                         init_opt_state)


def init_train_state(model: Model, tc: TrainConfig, key) -> dict:
    params = model.init(key)
    state = {"params": params, "opt": init_opt_state(params, tc)}
    if tc.grad_compress == "int8_ef":
        state["err"] = init_error_state(params)
    return state


def _split_microbatches(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model: Model, tc: TrainConfig):
    n_micro = tc.microbatch if tc.microbatch > 1 else 0

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, remat=tc.remat)
        return loss, metrics

    grad_fn = jax.grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if not n_micro:
            return grad_fn(params, batch)
        micro = _split_microbatches(batch, n_micro)

        def body(carry, mb):
            g_acc, m_acc = carry
            g, m = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": jnp.float32(0), "aux_loss": jnp.float32(0),
              "accuracy": jnp.float32(0), "n_tokens": jnp.float32(0)}
        (g, m), _ = jax.lax.scan(body, (g0, m0), micro)
        inv = 1.0 / n_micro
        return (jax.tree.map(lambda x: x * inv, g),
                jax.tree.map(lambda x: x * inv, m))

    def train_step(state: dict, batch: dict):
        params = state["params"]
        grads, metrics = compute_grads(params, batch)
        new_state = dict(state)
        if "err" in state:
            grads, new_state["err"] = compress_grads(grads, state["err"])
        new_params, new_opt, stats = adamw_update(params, grads,
                                                  state["opt"], tc)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, {**metrics, **stats}

    return train_step


def make_serve_step(model: Model):
    """DEPRECATED: one batched greedy decode step.

    The serving path moved to ``repro.engine`` (``make_decode_dispatch``
    for the K-step scanned dispatch, ``make_decode_step`` for the
    single-step form).  This shim keeps the historical
    ``(params, tokens, cache) -> (next_tok, logits, cache)`` contract for
    the dry-run cells and external callers."""
    import warnings
    warnings.warn("make_serve_step is deprecated; use "
                  "repro.engine.make_decode_dispatch (K-step dispatch) or "
                  "repro.engine.make_decode_step", DeprecationWarning,
                  stacklevel=2)
    from repro.engine.scheduler import make_decode_step
    step = make_decode_step(model)  # greedy SamplingParams

    def serve_step(params, tokens, cache):
        next_tok, logits, cache = step(params, tokens, cache)
        return next_tok, logits, cache
    return serve_step


def make_prefill_step(model: Model, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)
    return prefill_step
