"""Fault-tolerant training driver.

Design (scaled-down but structurally faithful to a 1000-node deployment):

* **Deterministic data** — batch ``t`` is a pure function of (seed, t), so
  any step is replayable after restart (data/synthetic.py).
* **Restart loop** — the driver body is wrapped in a retry loop: any step
  failure reloads the latest checkpoint and resumes.  On a real cluster the
  same binary is what the scheduler re-launches on node failure; because
  restore re-shards onto the *current* mesh, the job is elastic to a
  changed host count (checkpoint/store.py).
* **Heartbeat + step watchdog** — every step writes a heartbeat file
  (step, timestamp, host).  An external watchdog (or the cluster scheduler)
  kills stragglers whose heartbeat stalls; determinism makes the kill safe.
* **Checkpoint cadence** — atomic save every ``save_every`` steps and on
  clean exit; ``keep_last`` retained.

Usage (CPU-scale smoke):
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_arch, reduced as reduce_cfg
from repro.data import LanguageSpec, modality_extras, train_batch
from repro.launch.steps import init_train_state, make_train_step
from repro.models import build_model


def write_heartbeat(path: str, step: int, extra: dict | None = None) -> None:
    hb = {"step": step, "time": time.time(), "pid": os.getpid(),
          **(extra or {})}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(hb, f)
    os.replace(tmp, path)


def train_loop(model, tc: TrainConfig, *, batch_size: int, seq: int,
               steps: int, ckpt_dir: str, save_every: int = 50,
               keep_last: int = 3, style: bool = False,
               language: LanguageSpec | None = None,
               log_every: int = 10, init_params=None,
               max_restarts: int = 3) -> dict:
    """Run (or resume) training; returns the final state."""
    from repro import checkpoint as ckpt

    cfg = model.cfg
    spec = language or LanguageSpec(vocab=cfg.vocab_size, seed=tc.seed + 100)
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=0)
    hb_path = os.path.join(ckpt_dir, "heartbeat.json")
    os.makedirs(ckpt_dir, exist_ok=True)

    restarts = 0
    while True:  # restart loop: any failure past this point resumes
        try:
            latest = ckpt.latest(ckpt_dir)
            if latest is not None:
                state_shape = jax.eval_shape(
                    lambda k: init_train_state(model, tc, k),
                    jax.random.PRNGKey(tc.seed))
                state = ckpt.restore(ckpt_dir, latest, state_shape)
                start = latest
            else:
                state = init_train_state(model, tc,
                                         jax.random.PRNGKey(tc.seed))
                if init_params is not None:
                    # copy: the jitted step donates its state, and the
                    # caller's params (e.g. W_base) must survive
                    state["params"] = jax.tree.map(jnp.copy, init_params)
                start = 0

            last_metrics: dict = {}
            for t in range(start, steps):
                batch = train_batch(spec, tc.seed, t, batch_size, seq,
                                    style=style)
                batch.update(modality_extras(cfg, batch_size, seq,
                                             tc.seed, t))
                state, metrics = step_fn(state, batch)
                if (t + 1) % log_every == 0 or t + 1 == steps:
                    last_metrics = {k: float(v) for k, v in metrics.items()}
                    print(f"step {t+1:5d} loss={last_metrics['loss']:.4f} "
                          f"acc={last_metrics['accuracy']:.4f} "
                          f"lr={last_metrics['lr']:.2e} "
                          f"gnorm={last_metrics['grad_norm']:.3f}",
                          flush=True)
                write_heartbeat(hb_path, t + 1)
                if (t + 1) % save_every == 0:
                    ckpt.save(ckpt_dir, t + 1, state, keep_last=keep_last,
                              extra_meta={"arch": cfg.name})
            ckpt.save(ckpt_dir, steps, state, keep_last=keep_last,
                      extra_meta={"arch": cfg.name, "final": True})
            return {"state": state, "metrics": last_metrics}
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001 — restartable failure
            restarts += 1
            print(f"[train] step failure ({e!r}); restart {restarts}/"
                  f"{max_restarts} from latest checkpoint", flush=True)
            if restarts > max_restarts:
                raise


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--style", action="store_true",
                    help="train on the stylized corpus (SFT phase)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots_saveable"])
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatch=args.microbatch, remat=args.remat,
                     opt_state_dtype=args.opt_dtype,
                     grad_compress=args.grad_compress, seed=args.seed)
    model = build_model(cfg)
    t0 = time.time()
    out = train_loop(model, tc, batch_size=args.batch, seq=args.seq,
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     save_every=args.save_every, style=args.style)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"final loss {out['metrics'].get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
