from repro.models.lm import Model, build_model, layer_plan

__all__ = ["Model", "build_model", "layer_plan"]
