"""Attention: GQA self-attention (train/prefill/decode), cross-attention.

The train/prefill path uses **chunked online-softmax attention** (a pure-JAX
flash-attention formulation): scores are computed per (q-chunk, kv-chunk)
tile with a running (max, denom, acc) carry, so peak memory is
O(B * H * q_chunk * kv_chunk) instead of O(B * H * S^2).  Fully-masked kv
chunks are skipped with ``lax.cond`` (causal upper triangle, sliding-window
lower band), recovering the ~2x causal FLOP saving inside the scan.

GQA is computed in grouped form — q is reshaped to [B, S, Kv, G, hd] and
contracted against un-repeated k/v [B, S, Kv, hd] — so KV heads are never
materialized H/Kv times.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, rope_cos_sin, split
from repro.quant_runtime import qlinear

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd, dtype),
        "wk": dense_init(ks[1], D, Kv * hd, dtype),
        "wv": dense_init(ks[2], D, Kv * hd, dtype),
        "wo": dense_init(ks[3], H * hd, D, dtype),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((H * hd,), dtype)
        p["bias_k"] = jnp.zeros((Kv * hd,), dtype)
        p["bias_v"] = jnp.zeros((Kv * hd,), dtype)
    return p


def qkv_proj(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x [B, S, D] -> q [B,S,H,hd], k/v [B,S,Kv,hd]."""
    B, S, _ = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = qlinear.matmul(x, p["wq"])
    k = qlinear.matmul(x, p["wk"])
    v = qlinear.matmul(x, p["wv"])
    if "bias_q" in p:
        q = q + p["bias_q"].astype(q.dtype)
        k = k + p["bias_k"].astype(k.dtype)
        v = v + p["bias_v"].astype(v.dtype)
    return (q.reshape(B, S, H, hd), k.reshape(B, S, Kv, hd),
            v.reshape(B, S, Kv, hd))


# ---------------------------------------------------------------------------
# Core tile: grouped-GQA scores + online softmax update
# ---------------------------------------------------------------------------

def _tile_scores(q, k, softcap: float):
    """q [B,cq,Kv,G,hd], k [B,ck,Kv,hd] -> scores fp32 [B,Kv,G,cq,ck]."""
    hd = q.shape[-1]
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return s


# ---------------------------------------------------------------------------
# Chunked attention (train / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      softcap: float = 0.0, q_offsets=None,
                      kv_offsets=None, kv_lengths=None, q_chunk: int = 0,
                      kv_chunk: int = 0) -> jnp.ndarray:
    """Flash attention (custom-VJP online softmax, models/flash.py).

    q [B,Sq,H,hd]; k,v [B,Skv,Kv,hd].  ``kv_lengths`` [B] masks kv padding.
    ``q_offsets`` / ``kv_offsets`` [B] place the rows at global positions
    ``off + i`` for the causal / sliding-window masks (paged prefill-chunk
    path); None means position 0.  Returns [B, Sq, H, hd] in q.dtype.
    Padding to the tile grid and the grouped-GQA reshape happen here;
    masking of padded kv rows rides the same mask row as ``kv_lengths``.
    """
    from repro.runtime import flags
    from repro.models.flash import flash_attention
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    cq = min(q_chunk or flags["q_chunk"], Sq)
    ck = min(kv_chunk or flags["kv_chunk"], Skv)
    nq, nk = -(-Sq // cq), -(-Skv // ck)
    pq, pk = nq * cq - Sq, nk * ck - Skv
    qg = q.reshape(B, Sq, Kv, G, hd)
    if pq:
        qg = jnp.pad(qg, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    valid = jnp.full((B,), Skv, jnp.int32) if kv_lengths is None \
        else kv_lengths.astype(jnp.int32)
    mask = (jnp.arange(nk * ck)[None, :] < valid[:, None]).astype(jnp.float32)
    # Head sharding: tile tensors inside flash inherit from q/k/v layouts.
    from repro.runtime import (_mesh_axes, attn_shard_specs, constrain,
                               kv_repeat_factor)
    r = kv_repeat_factor(Kv, G)
    if r > 1:  # repeat KV heads so the head axis divides the model axis
        # gather the sequence FIRST so the repeat stays local; resharding
        # seq-sharded -> head-sharded THROUGH the broadcast triggers
        # GSPMD "involuntary full rematerialization" (llama-vision train)
        from jax.sharding import PartitionSpec as P
        _, dp, msz = _mesh_axes()
        if msz and msz > 1:
            k = constrain(k, P(dp, None, None, None))
            v = constrain(v, P(dp, None, None, None))
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
        qg = qg.reshape(B, qg.shape[1], Kv * r, G // r, hd)
        Kv, G = Kv * r, G // r
    q_spec, kv_spec = attn_shard_specs(Kv, G)
    qg = constrain(qg, q_spec)
    k, v = constrain(k, kv_spec), constrain(v, kv_spec)
    q_off = (jnp.zeros((B,), jnp.int32) if q_offsets is None
             else q_offsets.astype(jnp.int32))
    kv_off = (jnp.zeros((B,), jnp.int32) if kv_offsets is None
              else kv_offsets.astype(jnp.int32))
    out = flash_attention(qg, k, v, mask, q_off, kv_off, causal, window,
                          softcap, cq, ck)
    out = constrain(out, q_spec)
    return out.reshape(B, nq * cq, H, hd)[:, :Sq]


# ---------------------------------------------------------------------------
# Decode attention (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, lengths, *, window: int = 0,
                     softcap: float = 0.0) -> jnp.ndarray:
    """q [B,1,H,hd]; caches [B,S,Kv,hd]; lengths [B] = #valid entries
    (including the token just written).  Returns [B,1,H,hd]."""
    B, _, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    if k_cache.dtype.itemsize == 1:  # fp8 cache: upcast at the dot input
        k_cache = k_cache.astype(jnp.bfloat16)
        v_cache = v_cache.astype(jnp.bfloat16)
    G = H // Kv
    qg = q.reshape(B, 1, Kv, G, hd)
    s = _tile_scores(qg, k_cache, softcap)[..., 0, :]   # [B,Kv,G,S]
    kv_pos = jnp.arange(S)[None]                         # [1, S]
    mask = kv_pos < lengths[:, None]
    if window > 0:
        mask = mask & (kv_pos > (lengths[:, None] - 1 - window))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def write_paged_kv(pk, pv, k_new, v_new, wblk, woff):
    """Scatter one new kv [B,1,Kv,hd] into the block pools at each slot's
    write target (block id ``wblk[b]``, in-block offset ``woff[b]`` —
    computed once per step by ``engine.paged.alloc_step``; inactive slots
    point at the trash block)."""
    pk = pk.at[wblk, woff].set(k_new[:, 0].astype(pk.dtype))
    pv = pv.at[wblk, woff].set(v_new[:, 0].astype(pv.dtype))
    return pk, pv


def write_paged_kv_span(pk, pv, k_new, v_new, wblk, woff):
    """Scatter a prefill chunk's kv rows [B,C,Kv,hd] into the block pools
    at per-row targets (``wblk``/``woff`` [B,C] from
    ``engine.paged.span_targets``; pad rows, capacity overflows and
    shared-block rows point at the trash block)."""
    pk = pk.at[wblk, woff].set(k_new.astype(pk.dtype))
    pv = pv.at[wblk, woff].set(v_new.astype(pv.dtype))
    return pk, pv


def paged_prefill_attention(q, pk, pv, k_new, v_new, tbl, start, valid, *,
                            sliding_window=0, softcap=0.0) -> jnp.ndarray:
    """Prefill-chunk attention against the paged cache.

    q/k_new/v_new [B,C,{H|Kv},hd] are this chunk's rows at global positions
    ``start[b] + j`` (rows ``j >= valid[b]`` are pads); pools/tbl are the
    paged cache *before* the chunk's rows are written.  The kv buffer is
    assembled as gather(cache) overlaid with the fresh rows, so each query
    row attends exactly the prefix ``[0, pos]`` (window-banded for SWA) —
    and because flash's per-row online softmax treats trailing masked rows
    and omitted fully-masked leading tiles as exact identities, the result
    is **bitwise equal** to the corresponding rows of a one-shot prefill
    (given a same-dtype cache; fp8 caches trade that for memory).

    Rings gather **before** the write on purpose: a chunk's writes wrap the
    ring and would evict rows its own early queries still need.
    """
    from repro.engine.paged import gather_blocks
    B, C = k_new.shape[:2]
    bs = pk.shape[1]
    MB = tbl.shape[1]
    cap = MB * bs
    ring = bool(sliding_window) and cap == sliding_window
    if ring:
        W = sliding_window
        Wb = W + C
        base = jnp.maximum(start - (W - 1), 0)              # [B]
        pos = base[:, None] + jnp.arange(Wb)[None]          # [B, Wb]
        blk = jnp.take_along_axis(tbl, (pos % W) // bs, axis=1)
        off = (pos % W) % bs
        gk, gv = pk[blk, off], pv[blk, off]                 # [B,Wb,Kv,hd]
        kv_off = base
    else:
        pos = jnp.broadcast_to(jnp.arange(cap)[None], (B, cap))
        gk, gv = gather_blocks(pk, tbl), gather_blocks(pv, tbl)
        kv_off = jnp.zeros((B,), jnp.int32)
    rel = pos - start[:, None]                              # [B, Wb|cap]
    fresh = (rel >= 0) & (rel < C)
    idx = jnp.clip(rel, 0, C - 1)[..., None, None]
    fm = fresh[..., None, None]
    gk = jnp.where(fm, jnp.take_along_axis(k_new, idx, axis=1),
                   gk.astype(k_new.dtype))
    gv = jnp.where(fm, jnp.take_along_axis(v_new, idx, axis=1),
                   gv.astype(v_new.dtype))
    n_valid = start + valid - kv_off                        # local kv count
    return chunked_attention(q, gk, gv, causal=True, window=sliding_window,
                             softcap=softcap, q_offsets=start,
                             kv_offsets=kv_off, kv_lengths=n_valid)


def paged_verify_attention(q, pk, pv, k_new, v_new, tbl, start, valid, *,
                           sliding_window=0, softcap=0.0) -> jnp.ndarray:
    """Multi-token *verify* attention against the paged cache: row ``j`` of
    the chunk reproduces :func:`decode_attention` at position ``start + j``
    **operation for operation**.

    The speculative decoder (engine/spec.py) accepts a drafted token only
    when the verifier's logits agree with what the non-speculative engine
    would have computed at the same position — so unlike
    :func:`paged_prefill_attention` (flash tiles, online softmax), this
    path assembles the same kv buffer a decode step would see (block-table
    gather in slot order, fresh rows overlaid in pool dtype, the exact
    length/ring masks) and runs the plain-softmax decode math batched over
    the ``C`` chunk rows, keeping greedy speculative serving token-exact
    against per-token decode.  Memory is O(C * cap) per head group (ring:
    O(C * W) buffers) — the chunk is ``n_spec + 1`` rows, so this stays
    small; a production flash verify would trade the bitwise-decode mirror
    for tile math.
    """
    from repro.engine.paged import gather_blocks
    B, C = k_new.shape[:2]
    bs = pk.shape[1]
    MB = tbl.shape[1]
    cap = MB * bs
    H, hd = q.shape[2], q.shape[3]
    Kv = k_new.shape[2]
    G = H // Kv
    qg = q.reshape(B, C, Kv, G, hd)
    k_new = k_new.astype(pk.dtype)       # decode writes land in pool dtype
    v_new = v_new.astype(pv.dtype)
    ring = bool(sliding_window) and cap == sliding_window
    rows = jnp.arange(C)[None, :, None]                     # [1, C, 1]
    if ring:
        W = sliding_window
        slots = jnp.arange(W)[None, :]                      # [1, W]
        blk = tbl[:, (slots // bs)[0]]                      # [B, W]
        gk, gv = pk[blk, (slots % bs)[0]], pv[blk, (slots % bs)[0]]
        i_s = (slots - start[:, None]) % W                  # writing row
        fresh = (i_s[:, None, :] <= rows) & (i_s[:, None, :] < C)
        idx = jnp.clip(i_s, 0, C - 1)
        kf = jnp.take_along_axis(k_new, idx[..., None, None], axis=1)
        vf = jnp.take_along_axis(v_new, idx[..., None, None], axis=1)
        fm = fresh[..., None, None]                         # [B, C, W, 1, 1]
        kbuf = jnp.where(fm, kf[:, None], gk[:, None])      # [B,C,W,Kv,hd]
        vbuf = jnp.where(fm, vf[:, None], gv[:, None])
        eff = jnp.minimum(start[:, None] + rows[0, :, 0][None] + 1, W)
        mask = slots[:, None, :] < eff[..., None]           # [B, C, W]
        if kbuf.dtype.itemsize == 1:                        # fp8 cache
            kbuf = kbuf.astype(jnp.bfloat16)
            vbuf = vbuf.astype(jnp.bfloat16)
        s = jnp.einsum("bqkgh,bqckh->bkgqc", qg, kbuf,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqc,bqckh->bqkgh", p.astype(vbuf.dtype), vbuf,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, C, H, hd).astype(q.dtype)
    # linear layout: one shared buffer — a row's own/later positions are
    # overlaid fresh, and the per-row length mask hides rows past it (the
    # exact mask decode_attention applies)
    pos = jnp.broadcast_to(jnp.arange(cap)[None], (B, cap))
    gk, gv = gather_blocks(pk, tbl), gather_blocks(pv, tbl)
    rel = pos - start[:, None]
    fresh = (rel >= 0) & (rel < C)
    idx = jnp.clip(rel, 0, C - 1)[..., None, None]
    fm = fresh[..., None, None]
    kbuf = jnp.where(fm, jnp.take_along_axis(k_new, idx, axis=1), gk)
    vbuf = jnp.where(fm, jnp.take_along_axis(v_new, idx, axis=1), gv)
    if kbuf.dtype.itemsize == 1:                            # fp8 cache
        kbuf = kbuf.astype(jnp.bfloat16)
        vbuf = vbuf.astype(jnp.bfloat16)
    s = _tile_scores(qg, kbuf, softcap)                     # [B,Kv,G,C,cap]
    qpos = start[:, None] + jnp.arange(C)[None]             # [B, C]
    mask = pos[:, None, :] < (qpos + 1)[..., None]          # [B, C, cap]
    if sliding_window > 0:                                  # non-ring SWA
        mask = mask & (pos[:, None, :] > (qpos[..., None] - sliding_window))
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p.astype(vbuf.dtype), vbuf,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, H, hd).astype(q.dtype)


def paged_decode_attention(q, pk, pv, tbl, lengths, *, sliding_window=0,
                           softcap=0.0) -> jnp.ndarray:
    """Decode attention against paged K/V pools.

    q [B,1,H,hd]; pools [NB+1, bs, Kv, hd]; ``tbl`` [B, MB] block table.
    The gather reproduces the dense cache layout (linear positions, or ring
    positions when the table spans exactly the sliding window) so this is
    value-identical to :func:`decode_attention` on a contiguous cache.
    """
    from repro.engine.paged import gather_blocks
    gk = gather_blocks(pk, tbl)
    gv = gather_blocks(pv, tbl)
    cap = gk.shape[1]
    if sliding_window and cap == sliding_window:   # ring layout
        eff_len = jnp.minimum(lengths + 1, cap)
        return decode_attention(q, gk, gv, eff_len, softcap=softcap)
    return decode_attention(q, gk, gv, lengths + 1, window=sliding_window,
                            softcap=softcap)


# ---------------------------------------------------------------------------
# Layer-level wrappers
# ---------------------------------------------------------------------------

def self_attn_train(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                    causal: bool = True, positions=None) -> jnp.ndarray:
    """Full self-attention sublayer for train/prefill (no cache)."""
    B, S, D = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    if positions is None:
        positions = jnp.arange(S)[None]
    if cfg.rope_theta > 0 and causal:  # RoPE for decoder stacks
        cos, sin = rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    out = chunked_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                            softcap=cfg.attn_logit_softcap)
    return qlinear.matmul(out.reshape(B, S, -1), p["wo"])


def write_cache(cache_k, cache_v, k_new, v_new, lengths):
    """Scatter one new kv [B,1,Kv,hd] into caches at per-sample ``lengths``."""
    B = k_new.shape[0]
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, lengths].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, lengths].set(v_new[:, 0].astype(cache_v.dtype))
    return cache_k, cache_v


def self_attn_decode(p: dict, x: jnp.ndarray, cache: dict, cfg: ModelConfig):
    """One-token decode.  x [B,1,D]; cache {"k","v"} [B,S,Kv,hd] + lengths."""
    B = x.shape[0]
    q, k, v = qkv_proj(p, x, cfg)
    lengths = cache["lengths"]
    if cfg.rope_theta > 0:
        cos, sin = rope_cos_sin(lengths[:, None], cfg.resolved_head_dim,
                                cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ck, cv = write_cache(cache["k"], cache["v"], k, v, lengths)
    out = decode_attention(q, ck, cv, lengths + 1,
                           window=cfg.sliding_window,
                           softcap=cfg.attn_logit_softcap)
    y = qlinear.matmul(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": ck, "v": cv, "lengths": lengths}


# ---------------------------------------------------------------------------
# Cross-attention (VLM image layers, enc-dec decoder)
# ---------------------------------------------------------------------------

def cross_attn(p: dict, x: jnp.ndarray, memory: jnp.ndarray, cfg: ModelConfig,
               mem_lengths=None) -> jnp.ndarray:
    """x [B,Sq,D] attends to memory [B,Sm,D] (no causal mask, no RoPE)."""
    B, Sq, _ = x.shape
    Sm = memory.shape[1]
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = qlinear.matmul(x, p["wq"]).reshape(B, Sq, H, hd)
    k = qlinear.matmul(memory, p["wk"]).reshape(B, Sm, Kv, hd)
    v = qlinear.matmul(memory, p["wv"]).reshape(B, Sm, Kv, hd)
    out = chunked_attention(q, k, v, causal=False, kv_lengths=mem_lengths)
    return qlinear.matmul(out.reshape(B, Sq, -1), p["wo"])


def cross_attn_cached(p: dict, x: jnp.ndarray, mem_k, mem_v, cfg: ModelConfig,
                      mem_lengths=None) -> jnp.ndarray:
    """Decode-time cross-attention against precomputed memory K/V."""
    B, Sq, _ = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q = qlinear.matmul(x, p["wq"]).reshape(B, Sq, H, hd)
    Sm = mem_k.shape[1]
    lens = jnp.full((B,), Sm, jnp.int32) if mem_lengths is None else mem_lengths
    out = decode_attention(q, mem_k, mem_v, lens)
    return qlinear.matmul(out.reshape(B, Sq, -1), p["wo"])


def precompute_cross_kv(p: dict, memory: jnp.ndarray, cfg: ModelConfig):
    B, Sm, _ = memory.shape
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = qlinear.matmul(memory, p["wk"]).reshape(B, Sm, Kv, hd)
    v = qlinear.matmul(memory, p["wv"]).reshape(B, Sm, Kv, hd)
    return k, v
