"""Shared model building blocks: init helpers, norms, MLPs, RoPE, embeddings.

Everything is pure-functional: params are nested dicts of arrays, apply
functions take ``(params, x, ...)``.  Matmuls route through
``repro.quant_runtime.qlinear`` so that any weight leaf may transparently be
a :class:`QuantizedTensor` (the fp8 serving path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.quant_runtime import qlinear

# Compute dtype for activations; params carry their own dtype.
ACT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LLM init scales)."""
    std = in_dim ** -0.5
    return (std * jax.random.truncated_normal(
        key, -3.0, 3.0, (in_dim, out_dim), jnp.float32)).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(
        key, -3.0, 3.0, (vocab, d_model), jnp.float32)).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> dict:
    if cfg.norm == "layernorm":
        return {"norm_scale": jnp.ones((cfg.d_model,), dtype),
                "norm_bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"norm_scale": jnp.ones((cfg.d_model,), dtype)}


def apply_norm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm or LayerNorm depending on which params exist. fp32 internals."""
    x32 = x.astype(jnp.float32)
    if "norm_bias" in p:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["norm_scale"].astype(jnp.float32)
                + p["norm_bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + eps)
    return (y * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)


def norm_like(p: dict, width: int, dtype) -> dict:
    """A norm param dict for a non-d_model width (e.g. SSM gated norm)."""
    out = {"norm_scale": jnp.ones((width,), dtype)}
    if "norm_bias" in p:
        out["norm_bias"] = jnp.zeros((width,), dtype)
    return out


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int, dtype) -> dict:
    ks = split(key, 3)
    D = cfg.d_model
    if cfg.act == "swiglu":
        return {"w_gate": dense_init(ks[0], D, d_ff, dtype),
                "w_up": dense_init(ks[1], D, d_ff, dtype),
                "w_down": dense_init(ks[2], d_ff, D, dtype)}
    return {"w_up": dense_init(ks[0], D, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, D, dtype)}


def apply_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in p:
        g = qlinear.matmul(x, p["w_gate"])
        u = qlinear.matmul(x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = qlinear.matmul(x, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return qlinear.matmul(h, p["w_down"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions [...,S] -> cos/sin [..., S, head_dim/2] (fp32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, n_heads, head_dim]; cos/sin [..., S, head_dim/2]."""
    half = x.shape[-1] // 2
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    c = cos[..., None, :]  # broadcast over heads axis
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embed": embed_init(k1, cfg.vocab_size, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["w_head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed_tokens(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return qlinear.take(p["embed"], tokens).astype(ACT_DTYPE)


def lm_logits(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "w_head" in p:
        return qlinear.matmul(x, p["w_head"])
    # tied embedding: matmul_t keeps storage-mode tables quantized through
    # the transpose (the speculative draft's per-step hot path) instead of
    # dequantizing [V, D] every decode step
    return qlinear.matmul_t(x, p["embed"])


def last_token_logits(p: dict, x: jnp.ndarray,
                      lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """Logits of each row's last *real* position.

    x [B, S, D]; ``lengths`` [B] gives per-row true lengths for
    right-padded batches (the engine's packed multi-slot prefill); None
    means every row is full length.  Returns [B, V]."""
    B, S, _ = x.shape
    if lengths is None:
        return lm_logits(p, x[:, -1:])[:, 0]
    idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, S - 1)
    xg = x[jnp.arange(B), idx][:, None]
    return lm_logits(p, xg)[:, 0]


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (logits never fully materialized)
# ---------------------------------------------------------------------------

def chunked_xent(head_params: dict, x: jnp.ndarray, labels: jnp.ndarray,
                 chunk: int = 512, mask: jnp.ndarray | None = None):
    """Mean next-token cross-entropy, computed in sequence chunks.

    x [B, S, D], labels [B, S] int32 (-1 = ignore).  Avoids materializing the
    full [B, S, V] logits tensor: peak extra memory is [B, chunk, V_local].
    Returns (loss, n_correct, n_valid) — all fp32 scalars.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = max(S // chunk, 1)
    rem = S - n_chunks * chunk
    if mask is None:
        mask = labels >= 0

    def chunk_stats(xc, lc, mc):
        logits = lm_logits(head_params, xc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        lc_safe = jnp.maximum(lc, 0)
        tgt = jnp.take_along_axis(logits, lc_safe[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * mc
        correct = (jnp.argmax(logits, axis=-1) == lc_safe) & (mc > 0)
        return jnp.sum(nll), jnp.sum(correct.astype(jnp.float32)), jnp.sum(mc)

    def body(carry, args):
        l, c, n = chunk_stats(*args)
        return (carry[0] + l, carry[1] + c, carry[2] + n), None

    xs = (x[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D).swapaxes(0, 1),
          labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1),
          mask[:, : n_chunks * chunk].reshape(B, n_chunks, chunk)
              .astype(jnp.float32).swapaxes(0, 1))
    init = (jnp.float32(0), jnp.float32(0), jnp.float32(0))
    (tot, cor, n), _ = jax.lax.scan(jax.checkpoint(body), init, xs)
    if rem:
        l, c, m = chunk_stats(x[:, -rem:], labels[:, -rem:],
                              mask[:, -rem:].astype(jnp.float32))
        tot, cor, n = tot + l, cor + c, n + m
    n = jnp.maximum(n, 1.0)
    return tot / n, cor / n, n
