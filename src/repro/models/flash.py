"""Flash attention in pure JAX with a custom VJP.

Reverse-mode through a ``lax.scan`` online-softmax stacks every tile's
residuals — O(S^2) memory, exactly what flash attention exists to avoid.
This module gives attention the flash memory bound in both directions:

* forward: online-softmax over kv tiles; saves only (out, lse);
* backward: recomputes tile scores from (q, k, v, lse) — dq accumulated per
  q-tile, dk/dv accumulated across q-tiles in carries the size of k/v.

Implementation note: the tile loops are ``lax.fori_loop``, NOT ``lax.scan``.
Inside a custom-VJP fwd/bwd the loops are never differentiated, and scan's
partial-evaluation machinery hoists loop-invariant tile quantities (masks,
position tiles, init-carry-derived values) out of enclosing layer scans,
materializing all [nq, nk, B, Kv, G, cq, ck] tiles at once — observed as a
persistent 8 GiB/device buffer on glm4-9b train_4k.  fori_loop has no
ys/residual machinery, so tiles stay transient by construction.

Tiles that are fully masked (above the causal diagonal / left of the
sliding window) are skipped with ``lax.cond`` in both passes.

Layout: q [B, Sq, Kv, G, hd] (grouped GQA — kv heads never repeated),
k/v [B, Skv, Kv, hd].  ``mask`` is an f32 [B, Skv] validity row (1/0).
All softmax math in fp32; matmul inputs stay in the input dtype.

This is also the blueprint the TPU Pallas flash kernel follows; the pure-JAX
version keeps every op MXU-shaped so XLA:TPU emits fused tiles from it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _tile_scores(qc, kc, softcap: float):
    """qc [B,cq,Kv,G,hd], kc [B,ck,Kv,hd] -> fp32 [B,Kv,G,cq,ck]."""
    hd = qc.shape[-1]
    s = jnp.einsum("bqkgh,bckh->bkgqc", qc, kc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _tile_mask(q_pos, kv_pos, mask_row, causal: bool, window: int):
    """[B,1,1,cq,ck] boolean tile mask.  ``q_pos`` [B,cq] / ``kv_pos``
    [B,ck] carry per-batch global positions (the paged prefill-chunk path
    offsets both; the classic path passes broadcast rows)."""
    cq = q_pos.shape[1]
    m = (mask_row > 0)[:, None, None, None, :] \
        & jnp.ones((1, 1, 1, cq, 1), bool)
    if causal:
        cm = kv_pos[:, None, :] <= q_pos[:, :, None]        # [B,cq,ck]
        m = m & cm[:, None, None]
    if window > 0:
        wm = kv_pos[:, None, :] > (q_pos[:, :, None] - window)
        m = m & wm[:, None, None]
    return m


def _dyn_chunk(x, i, c, axis=1):
    """Slice chunk i of length c along `axis` (static axis)."""
    starts = [0] * x.ndim
    starts[axis] = i * c
    sizes = list(x.shape)
    sizes[axis] = c
    return jax.lax.dynamic_slice(x, starts, sizes)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def flash_attention(q, k, v, mask, q_off, kv_off, causal: bool, window: int,
                    softcap: float, cq: int, ck: int):
    """q [B,Sq,Kv,G,hd]; k,v [B,Skv,Kv,hd]; mask f32 [B,Skv].

    ``q_off`` / ``kv_off`` [B] int32 shift the *global positions* the
    causal / sliding-window masks see: row ``i`` of q sits at position
    ``q_off[b] + i`` and kv row ``j`` at ``kv_off[b] + j``.  Zeros recover
    the classic from-position-0 layout; the paged prefill-chunk path uses
    them to attend mid-sequence rows against a cache whose leading
    fully-masked tiles are omitted (an exact identity in the online-softmax
    update, so results stay bitwise equal to the full-length call).
    Returns [B,Sq,Kv,G,hd] in q.dtype."""
    out, _ = _fwd(q, k, v, mask, q_off, kv_off, causal, window, softcap,
                  cq, ck)
    return out


def _data_zero(ref) -> jnp.ndarray:
    """Scalar fp32 zero that formally depends on ``ref``.

    The mask row is often a trace-time constant (jnp.ones).  Everything
    derived from (mask, iota positions) is then a constant subgraph, which
    partial evaluation hoists out of the tile loops and materializes for
    ALL [nq, nk, ...] tiles at once — O(S^2) persistent memory.  Tying the
    mask to a data tensor keeps the tile masks inside the loops; XLA folds
    the zero after partitioning."""
    return (ref.reshape(-1)[0] * 0).astype(jnp.float32)


def _fwd(q, k, v, mask, q_off, kv_off, causal, window, softcap, cq, ck):
    B, Sq, Kv, G, hd = q.shape
    Skv = k.shape[1]
    mask = mask + _data_zero(k)
    nq, nk = Sq // cq, Skv // ck

    out_buf = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    lse_buf = jnp.zeros((B, Sq, Kv, G), jnp.float32)

    def q_body(qi, bufs):
        out_buf, lse_buf = bufs
        qc = _dyn_chunk(q, qi, cq)
        q_pos = q_off[:, None] + qi * cq + jnp.arange(cq)[None]

        def kv_body(ki, carry):
            # NOTE: no lax.cond tile-skipping here.  cond's partial-eval
            # forces per-tile branch residuals to cross the known/unknown
            # boundary, stacking all [nq, nk, ...] tiles (8-32 GiB/device
            # observed).  Fully-masked tiles are computed and discarded;
            # the causal 2x FLOP saving is recovered by the triangle
            # iteration in EXPERIMENTS.md §Perf.
            kv_pos = kv_off[:, None] + ki * ck + jnp.arange(ck)[None]
            m, l, acc = carry
            kc = _dyn_chunk(k, ki, ck)
            vc = _dyn_chunk(v, ki, ck)
            mc = jax.lax.dynamic_slice(mask, (0, ki * ck), (B, ck))
            s = _tile_scores(qc, kc, softcap)
            tm = _tile_mask(q_pos, kv_pos, mc, causal, window)
            s = jnp.where(tm, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(tm, p, 0.0)
            corr = jnp.where(m <= NEG_INF, 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            return (m_new, l_new, acc * corr[..., None] + pv)

        shape = (B, Kv, G, cq)
        init = (jnp.full(shape, NEG_INF, jnp.float32),
                jnp.zeros(shape, jnp.float32),
                jnp.zeros(shape + (hd,), jnp.float32))
        m, l, acc = jax.lax.fori_loop(0, nk, kv_body, init)
        l_safe = jnp.maximum(l, 1e-30)
        o = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)  # [B,cq,Kv,G,hd]
        lse = (m + jnp.log(l_safe)).transpose(0, 3, 1, 2)       # [B,cq,Kv,G]
        out_buf = jax.lax.dynamic_update_slice(
            out_buf, o, (0, qi * cq, 0, 0, 0))
        lse_buf = jax.lax.dynamic_update_slice(
            lse_buf, lse, (0, qi * cq, 0, 0))
        return out_buf, lse_buf

    out_buf, lse_buf = jax.lax.fori_loop(0, nq, q_body, (out_buf, lse_buf))
    return out_buf.astype(q.dtype), lse_buf


def _fwd_vjp(q, k, v, mask, q_off, kv_off, causal, window, softcap, cq, ck):
    out, lse = _fwd(q, k, v, mask, q_off, kv_off, causal, window, softcap,
                    cq, ck)
    return out, (q, k, v, mask, q_off, kv_off, out, lse)


def _bwd_vjp(causal, window, softcap, cq, ck, res, dout):
    q, k, v, mask, q_off, kv_off, out, lse = res
    mask = mask + _data_zero(dout)
    B, Sq, Kv, G, hd = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // cq, Skv // ck
    tau = hd ** -0.5

    dout32 = dout.astype(jnp.float32)
    # D_i = sum_h dout * out  (per query row)
    Drow = jnp.sum(dout32 * out.astype(jnp.float32), axis=-1)  # [B,Sq,Kv,G]

    dq_buf = jnp.zeros((B, Sq, Kv, G, hd), jnp.float32)
    dk_buf = jnp.zeros((B, Skv, Kv, hd), jnp.float32)
    dv_buf = jnp.zeros((B, Skv, Kv, hd), jnp.float32)

    def q_body(qi, bufs):
        dq_buf, dk_buf, dv_buf = bufs
        qc = _dyn_chunk(q, qi, cq)
        doc = _dyn_chunk(dout32, qi, cq)
        q_pos = q_off[:, None] + qi * cq + jnp.arange(cq)[None]
        lct = _dyn_chunk(lse, qi, cq).transpose(0, 2, 3, 1)   # [B,Kv,G,cq]
        Dct = _dyn_chunk(Drow, qi, cq).transpose(0, 2, 3, 1)

        def kv_body(ki, inner):
            dq_c, dk_buf, dv_buf = inner
            kv_pos = kv_off[:, None] + ki * ck + jnp.arange(ck)[None]
            kc = _dyn_chunk(k, ki, ck)
            vc = _dyn_chunk(v, ki, ck)
            mc = jax.lax.dynamic_slice(mask, (0, ki * ck), (B, ck))
            s = _tile_scores(qc, kc, softcap)          # capped scores
            tm = _tile_mask(q_pos, kv_pos, mc, causal, window)
            s_m = jnp.where(tm, s, NEG_INF)
            p = jnp.exp(s_m - lct[..., None])          # [B,Kv,G,cq,ck]
            p = jnp.where(tm, p, 0.0)
            dv_t = jnp.einsum("bkgqc,bqkgh->bckh", p, doc,
                              preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgh,bckh->bkgqc", doc,
                            vc.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - Dct[..., None])             # d(capped scores)
            if softcap > 0:
                ds = ds * (1.0 - (s / softcap) ** 2)
            ds = ds * tau
            dq_t = jnp.einsum("bkgqc,bckh->bqkgh", ds,
                              kc.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dk_t = jnp.einsum("bkgqc,bqkgh->bckh", ds,
                              qc.astype(jnp.float32),
                              preferred_element_type=jnp.float32)
            dk_buf = jax.lax.dynamic_update_slice(
                dk_buf,
                jax.lax.dynamic_slice(
                    dk_buf, (0, ki * ck, 0, 0), (B, ck, Kv, hd)) + dk_t,
                (0, ki * ck, 0, 0))
            dv_buf = jax.lax.dynamic_update_slice(
                dv_buf,
                jax.lax.dynamic_slice(
                    dv_buf, (0, ki * ck, 0, 0), (B, ck, Kv, hd)) + dv_t,
                (0, ki * ck, 0, 0))
            return (dq_c + dq_t, dk_buf, dv_buf)

        dq0 = jnp.zeros((B, cq, Kv, G, hd), jnp.float32)
        dq_c, dk_buf, dv_buf = jax.lax.fori_loop(
            0, nk, kv_body, (dq0, dk_buf, dv_buf))
        dq_buf = jax.lax.dynamic_update_slice(
            dq_buf, dq_c, (0, qi * cq, 0, 0, 0))
        return dq_buf, dk_buf, dv_buf

    dq_buf, dk_buf, dv_buf = jax.lax.fori_loop(
        0, nq, q_body, (dq_buf, dk_buf, dv_buf))
    return (dq_buf.astype(q.dtype), dk_buf.astype(k.dtype),
            dv_buf.astype(v.dtype), jnp.zeros_like(mask),
            jnp.zeros_like(q_off), jnp.zeros_like(kv_off))


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
