"""Model assembly for all six families (dense / moe / ssm / hybrid / encdec /
vlm) behind one functional API.

Layer stacking: every architecture is decomposed into a repeating **period**
of layer specs (the smallest repeating group — 1 layer for dense, 8 for
Jamba's 1:7 attn:mamba interleave, 5 for Llama-Vision's cross-attn cadence).
Params for each position in the period are stacked on a leading
``[n_periods, ...]`` axis and the forward pass ``lax.scan``s over periods, so
HLO size is O(period), not O(depth) — a 95-layer model lowers as fast as a
5-layer one.

The same layer code serves train, prefill and decode; decode threads a cache
pytree (stacked the same way) through the scan.  All matmuls route through
``qlinear`` so fp8-quantized parameter trees run the identical code path.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.common import (ACT_DTYPE, apply_mlp, apply_norm,
                                 chunked_xent, embed_tokens, init_embed,
                                 init_mlp, init_norm, last_token_logits,
                                 lm_logits, split)

LayerSpec = tuple[str, str]  # (mixer, ffn)


# ---------------------------------------------------------------------------
# Period layout per family
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig):
    """Returns (prefix_specs, n_prefix, period_specs, n_periods)."""
    fam = cfg.family
    if fam == "dense":
        return [], 0, [("attn", "mlp")], cfg.n_layers
    if fam == "moe":
        k = cfg.first_k_dense
        prefix = [("attn", "mlp_dense")] if k else []
        return prefix, k, [("attn", "moe")], cfg.n_layers - k
    if fam == "ssm":
        return [], 0, [("mamba", "none")], cfg.n_layers
    if fam == "hybrid":
        per = cfg.attn_every
        specs = []
        for i in range(per):
            mixer = "attn" if i == per // 2 else "mamba"
            ffn = "moe" if (cfg.moe_every and i % cfg.moe_every == cfg.moe_offset) \
                else "mlp"
            specs.append((mixer, ffn))
        return [], 0, specs, cfg.n_layers // per
    if fam == "vlm":
        ce = cfg.cross_attn_every
        specs = [("attn", "mlp")] * (ce - 1) + [("cross", "mlp")]
        return [], 0, specs, cfg.n_layers // ce
    if fam == "encdec":
        # handled specially (two stacks); expose the decoder period here
        return [], 0, [("attn_cross", "mlp")], cfg.n_dec_layers
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    mixer, ffn = spec
    ks = split(key, 4)
    p: dict = {}
    if mixer in ("attn", "enc_attn"):
        p["ln1"] = init_norm(cfg, dtype)
        p["attn"] = A.init_attn(ks[0], cfg, dtype)
    elif mixer == "mamba":
        p["ln1"] = init_norm(cfg, dtype)
        p["mamba"] = SSM.init_mamba(ks[0], cfg, dtype)
    elif mixer == "cross":
        p["ln1"] = init_norm(cfg, dtype)
        p["cross"] = A.init_attn(ks[0], cfg, dtype)
    elif mixer == "attn_cross":
        p["ln1"] = init_norm(cfg, dtype)
        p["attn"] = A.init_attn(ks[0], cfg, dtype)
        p["ln_x"] = init_norm(cfg, dtype)
        p["xattn"] = A.init_attn(ks[3], cfg, dtype)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ln2"] = init_norm(cfg, dtype)
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_ff, dtype)
    elif ffn == "mlp_dense":
        p["ln2"] = init_norm(cfg, dtype)
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_ff_dense or cfg.d_ff, dtype)
    elif ffn == "moe":
        p["ln2"] = init_norm(cfg, dtype)
        p["moe"] = MOE.init_moe(ks[2], cfg, dtype)
    elif ffn != "none":
        raise ValueError(ffn)
    return p


def apply_layer_train(p: dict, x, cfg: ModelConfig, spec: LayerSpec, *,
                      memory=None):
    """Pre-norm residual layer.  Returns (x, aux_loss).

    Every sublayer output is constrained to the (dp, seq-sharded) residual
    layout BEFORE the residual add: the row-parallel out-projections then
    lower to reduce-scatter instead of all-reduce+slice (Megatron SP) —
    without this GSPMD all-reduces full [B,S,D] partials per sublayer
    (llama-vision train: 3.1 TB/chip of all-reduce observed)."""
    from repro.runtime import residual_constraint as rc
    mixer, ffn = spec
    aux = jnp.float32(0.0)
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    if mixer == "attn":
        x = x + rc(A.self_attn_train(p["attn"], h, cfg, causal=True))
    elif mixer == "enc_attn":
        x = x + rc(A.self_attn_train(p["attn"], h, cfg, causal=False))
    elif mixer == "mamba":
        x = x + rc(SSM.mamba_train(p["mamba"], h, cfg))
    elif mixer == "cross":
        x = x + rc(A.cross_attn(p["cross"], h, memory, cfg))
    elif mixer == "attn_cross":
        x = x + rc(A.self_attn_train(p["attn"], h, cfg, causal=True))
        hx = apply_norm(p["ln_x"], x, cfg.norm_eps)
        x = x + rc(A.cross_attn(p["xattn"], hx, memory, cfg))
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = MOE.apply_moe(p["moe"], h2, cfg)
            x = x + rc(y)
        else:
            x = x + rc(apply_mlp(p["mlp"], h2))
    return x, aux


def _kv_eff(cfg: ModelConfig) -> int:
    """Effective KV heads in decode caches (GQA repeat-sharding — see
    runtime.kv_repeat_factor): Kv*r so the cache head axis shards over
    `model` instead of replicating."""
    from repro.runtime import kv_repeat_factor
    Kv = cfg.n_kv_heads
    if not Kv:
        return 0
    return Kv * kv_repeat_factor(Kv, cfg.n_heads // Kv, for_cache=True)


def _cache_dtype():
    from repro.runtime import flags
    return jnp.dtype(flags["kv_cache_dtype"])


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     cache_len: int, mem_len: int = 0) -> dict:
    """Zero decode cache for one layer.  SWA layers use a ring of window size."""
    mixer, _ = spec
    Kv, hd = _kv_eff(cfg), cfg.resolved_head_dim
    cdt = _cache_dtype()
    if mixer in ("attn", "enc_attn"):
        sc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        return {"k": jnp.zeros((batch, sc, Kv, hd), cdt),
                "v": jnp.zeros((batch, sc, Kv, hd), cdt)}
    if mixer == "mamba":
        return SSM.init_mamba_cache(cfg, batch)
    if mixer == "cross":
        return {"mk": jnp.zeros((batch, mem_len, Kv, hd), cdt),
                "mv": jnp.zeros((batch, mem_len, Kv, hd), cdt)}
    if mixer == "attn_cross":
        sc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        return {"k": jnp.zeros((batch, sc, Kv, hd), cdt),
                "v": jnp.zeros((batch, sc, Kv, hd), cdt),
                "mk": jnp.zeros((batch, mem_len, Kv, hd), cdt),
                "mv": jnp.zeros((batch, mem_len, Kv, hd), cdt)}
    raise ValueError(mixer)


def init_layer_cache_paged(cfg: ModelConfig, spec: LayerSpec, batch: int,
                           num_blocks: int, block_size: int) -> dict:
    """Paged decode cache for one layer: attention K/V become global block
    pools ``pk``/``pv`` ``[num_blocks + 1, bs, Kv, hd]`` (the last block is
    the trash sink — engine/paged.py); SSM state has no sequence axis to
    page and stays per-slot dense."""
    mixer, _ = spec
    Kv, hd = _kv_eff(cfg), cfg.resolved_head_dim
    cdt = _cache_dtype()
    if mixer in ("attn", "enc_attn"):
        return {"pk": jnp.zeros((num_blocks + 1, block_size, Kv, hd), cdt),
                "pv": jnp.zeros((num_blocks + 1, block_size, Kv, hd), cdt)}
    if mixer == "mamba":
        return SSM.init_mamba_cache(cfg, batch)
    raise ValueError(f"paged cache not supported for mixer {mixer!r}")


def _ring_write(cache_k, cache_v, k_new, v_new, lengths):
    """Write one kv into a ring cache at slot lengths % capacity."""
    cap = cache_k.shape[1]
    bidx = jnp.arange(k_new.shape[0])
    slot = lengths % cap
    ck = cache_k.at[bidx, slot].set(k_new[:, 0].astype(cache_k.dtype))
    cv = cache_v.at[bidx, slot].set(v_new[:, 0].astype(cache_v.dtype))
    return ck, cv


def _attn_decode(p, x, cache, lengths, cfg: ModelConfig):
    """Self-attn decode honoring ring (SWA) vs full caches."""
    B = x.shape[0]
    q, k, v = A.qkv_proj(p, x, cfg)
    if cfg.rope_theta > 0:
        cos, sin = A.rope_cos_sin(lengths[:, None], cfg.resolved_head_dim,
                                  cfg.rope_theta)
        q = A.apply_rope(q, cos, sin)
        k = A.apply_rope(k, cos, sin)
    r = _kv_eff(cfg) // cfg.n_kv_heads
    if r > 1:  # repeat-sharded cache (see _kv_eff)
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    cap = cache["k"].shape[1]
    if cfg.sliding_window and cap == cfg.sliding_window:
        ck, cv = _ring_write(cache["k"], cache["v"], k, v, lengths)
        eff_len = jnp.minimum(lengths + 1, cap)
        out = A.decode_attention(q, ck, cv, eff_len, softcap=cfg.attn_logit_softcap)
    else:
        ck, cv = A.write_cache(cache["k"], cache["v"], k, v, lengths)
        out = A.decode_attention(q, ck, cv, lengths + 1,
                                 window=cfg.sliding_window,
                                 softcap=cfg.attn_logit_softcap)
    from repro.quant_runtime import qlinear
    y = qlinear.matmul(out.reshape(B, 1, -1), p["wo"])
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ck, cv
    return y, new_cache


def cow_copy_blocks(pcache: dict, src, dst, any_flag):
    """Round-level copy-on-write for the paged pools: copy block ``src[b]``'s
    rows into block ``dst[b]`` in every attention layer's ``pk``/``pv``
    pool (``stack`` and ``prefix`` groups; pool leaves are
    ``[n_periods, num_blocks + 1, bs, Kv, hd]``).

    This materializes the private copy ``alloc_span(..., cow=True)``
    rewired a slot's shared first span block to (engine/spec.py): the copy
    must land *before* any draft or verify write of the round touches the
    block, which is why it happens once per round here rather than inside
    the per-layer write (the decode path's in-layer ``cow_src`` copy in
    :func:`_attn_decode_paged` is the single-step analogue).  Slots without
    a CoW carry ``src == dst`` (both the trash index), so their scatter is
    a trash-block no-op; the whole copy is gated on ``any_flag`` because
    at most one round per partial prefix hit ever CoWs.
    """
    def copy_group(group):
        out = {}
        for lk, lv in group.items():
            nl = dict(lv)
            for name in ("pk", "pv"):
                if name in lv:
                    nl[name] = lv[name].at[:, dst].set(lv[name][:, src])
            out[lk] = nl
        return out

    def do(c):
        new = dict(c)
        for grp in ("stack", "prefix"):
            if grp in c:
                new[grp] = copy_group(c[grp])
        return new

    return jax.lax.cond(any_flag, do, lambda c: dict(c), pcache)


def _attn_decode_paged(p, x, cache, pctx, cfg: ModelConfig):
    """Self-attn decode against the paged block pool.

    Mirrors :func:`_attn_decode` exactly for active slots: the new K/V lands
    at the slot's write target (``pctx["wblk"]/["woff"]``, precomputed once
    per step — trash block for inactive slots), and attention runs over the
    block-table gather, which reproduces the dense cache layout (linear
    positions, or ring positions for SWA) value-for-value.

    When ``pctx["cow_src"]`` is present (refcounted prefix caching), the
    write block is first overwritten with the rows of its copy-on-write
    source — the identity when ``cow_src == wblk`` (no sharing), a real
    block copy when ``alloc_step`` rewired the slot off a shared block."""
    B = x.shape[0]
    lengths = pctx["lengths"]
    q, k, v = A.qkv_proj(p, x, cfg)
    if cfg.rope_theta > 0:
        cos, sin = A.rope_cos_sin(lengths[:, None], cfg.resolved_head_dim,
                                  cfg.rope_theta)
        q = A.apply_rope(q, cos, sin)
        k = A.apply_rope(k, cos, sin)
    r = _kv_eff(cfg) // cfg.n_kv_heads
    if r > 1:  # repeat-sharded cache (see _kv_eff)
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    pk, pv = cache["pk"], cache["pv"]
    if "cow_src" in pctx:
        # at most one slot CoWs per step and most steps none at all, so
        # the block copy (a whole-block gather+scatter per layer) is
        # gated on the step-wide predicate; skipping the identity copy
        # (cow_src == wblk) is a bitwise no-op
        def _copy(pools):
            a, b = pools
            return (a.at[pctx["wblk"]].set(a[pctx["cow_src"]]),
                    b.at[pctx["wblk"]].set(b[pctx["cow_src"]]))
        pk, pv = jax.lax.cond(pctx["cow_any"], _copy, lambda p: p, (pk, pv))
    pk, pv = A.write_paged_kv(pk, pv, k, v, pctx["wblk"], pctx["woff"])
    out = A.paged_decode_attention(q, pk, pv, pctx["tbl"], lengths,
                                   sliding_window=cfg.sliding_window,
                                   softcap=cfg.attn_logit_softcap)
    from repro.quant_runtime import qlinear
    y = qlinear.matmul(out.reshape(B, 1, -1), p["wo"])
    return y, {**cache, "pk": pk, "pv": pv}


def _attn_prefill_paged(p, x, cache, pctx, cfg: ModelConfig):
    """Self-attn over one prefill chunk against the paged pool: the chunk's
    rows (global positions ``start[b] + j``) attend the slot's cached
    prefix plus themselves, then land in the pool at the precomputed span
    targets (pads / overflows / shared blocks route to trash)."""
    B, C, _ = x.shape
    q, k, v = A.qkv_proj(p, x, cfg)
    if cfg.rope_theta > 0:
        pos = pctx["start"][:, None] + jnp.arange(C)[None]
        cos, sin = A.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = A.apply_rope(q, cos, sin)
        k = A.apply_rope(k, cos, sin)
    r = _kv_eff(cfg) // cfg.n_kv_heads
    if r > 1:  # repeat-sharded cache (see _kv_eff)
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    out = A.paged_prefill_attention(q, cache["pk"], cache["pv"], k, v,
                                    pctx["tbl"], pctx["start"],
                                    pctx["valid"],
                                    sliding_window=cfg.sliding_window,
                                    softcap=cfg.attn_logit_softcap)
    pk, pv = A.write_paged_kv_span(cache["pk"], cache["pv"], k, v,
                                   pctx["wblk"], pctx["woff"])
    from repro.quant_runtime import qlinear
    y = qlinear.matmul(out.reshape(B, C, -1), p["wo"])
    return y, {**cache, "pk": pk, "pv": pv}


def apply_layer_prefill_paged(p: dict, x, cache: dict, pctx: dict,
                              cfg: ModelConfig, spec: LayerSpec):
    """Prefill-chunk variant of :func:`apply_layer_decode_paged`: attention
    writes the chunk's rows into the pool, Mamba/SSM layers thread their
    per-slot recurrent state chunk-to-chunk."""
    mixer, ffn = spec
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("attn", "enc_attn"):
        y, cache = _attn_prefill_paged(p["attn"], h, cache, pctx, cfg)
        x = x + y
    elif mixer == "mamba":
        y, cache = SSM.mamba_prefill_chunk(p["mamba"], x, h, cfg, cache,
                                           pctx["valid"])
        x = x + y
    else:
        raise ValueError(f"paged prefill not supported for mixer {mixer!r}")
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            # full capacity: the chunk batch mixes unrelated slots' rows
            # (and pad garbage), so capacity competition would couple
            # tokens across slots and break chunked == one-shot exactness
            y, _ = MOE.apply_moe(p["moe"], h2, cfg, full_capacity=True)
            x = x + y
        else:
            x = x + apply_mlp(p["mlp"], h2)
    return x, cache


def _attn_verify_paged(p, x, cache, pctx, cfg: ModelConfig):
    """Self-attn over one speculative-verify chunk: row ``j`` mirrors a
    decode step at position ``start[b] + j`` operation-for-operation
    (attention.paged_verify_attention), then the chunk's rows land at the
    precomputed span targets (pads / overflows route to trash)."""
    B, C, _ = x.shape
    q, k, v = A.qkv_proj(p, x, cfg)
    if cfg.rope_theta > 0:
        pos = pctx["start"][:, None] + jnp.arange(C)[None]
        cos, sin = A.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = A.apply_rope(q, cos, sin)
        k = A.apply_rope(k, cos, sin)
    r = _kv_eff(cfg) // cfg.n_kv_heads
    if r > 1:  # repeat-sharded cache (see _kv_eff)
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    out = A.paged_verify_attention(q, cache["pk"], cache["pv"], k, v,
                                   pctx["tbl"], pctx["start"], pctx["valid"],
                                   sliding_window=cfg.sliding_window,
                                   softcap=cfg.attn_logit_softcap)
    pk, pv = A.write_paged_kv_span(cache["pk"], cache["pv"], k, v,
                                   pctx["wblk"], pctx["woff"])
    from repro.quant_runtime import qlinear
    y = qlinear.matmul(out.reshape(B, C, -1), p["wo"])
    return y, {**cache, "pk": pk, "pv": pv}


def apply_layer_verify_paged(p: dict, x, cache: dict, pctx: dict,
                             cfg: ModelConfig, spec: LayerSpec):
    """Speculative-verify variant of :func:`apply_layer_decode_paged`: each
    chunk row reproduces per-token decode bitwise — attention mirrors the
    decode softmax over the gathered-and-overlaid table, Mamba/SSM layers
    step the exact recurrence (ssm.mamba_verify_chunk), and capacity-routed
    MoE runs dropless so the chunk batch (which mixes slots' rows and pad
    garbage) cannot couple tokens through expert queues (outputs equal
    decode's whenever decode's own routing doesn't overflow a queue, as
    with chunked prefill)."""
    mixer, ffn = spec
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("attn", "enc_attn"):
        y, cache = _attn_verify_paged(p["attn"], h, cache, pctx, cfg)
        x = x + y
    elif mixer == "mamba":
        y, cache = SSM.mamba_verify_chunk(p["mamba"], x, h, cfg, cache,
                                          pctx["valid"])
        x = x + y
    else:
        raise ValueError(f"speculative verify not supported for mixer "
                         f"{mixer!r}")
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, _ = MOE.apply_moe(p["moe"], h2, cfg, full_capacity=True)
            x = x + y
        else:
            x = x + apply_mlp(p["mlp"], h2)
    return x, cache


def apply_layer_decode_paged(p: dict, x, cache: dict, pctx: dict,
                             cfg: ModelConfig, spec: LayerSpec):
    """Paged variant of :func:`apply_layer_decode`; Mamba/SSM layers keep
    their contiguous per-slot state and are routed around the pool."""
    mixer, ffn = spec
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("attn", "enc_attn"):
        y, cache = _attn_decode_paged(p["attn"], h, cache, pctx, cfg)
        x = x + y
    elif mixer == "mamba":
        y, cache = SSM.mamba_decode(p["mamba"], h, cache, cfg)
        x = x + y
    else:
        raise ValueError(f"paged decode not supported for mixer {mixer!r}")
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, _ = MOE.apply_moe(p["moe"], h2, cfg)
            x = x + y
        else:
            x = x + apply_mlp(p["mlp"], h2)
    return x, cache


def apply_layer_decode(p: dict, x, cache: dict, lengths, cfg: ModelConfig,
                       spec: LayerSpec):
    mixer, ffn = spec
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    if mixer in ("attn", "enc_attn"):
        y, cache = _attn_decode(p["attn"], h, cache, lengths, cfg)
        x = x + y
    elif mixer == "mamba":
        y, cache = SSM.mamba_decode(p["mamba"], h, cache, cfg)
        x = x + y
    elif mixer == "cross":
        x = x + A.cross_attn_cached(p["cross"], h, cache["mk"], cache["mv"], cfg)
    elif mixer == "attn_cross":
        sub = {"k": cache["k"], "v": cache["v"]}
        y, sub = _attn_decode(p["attn"], h, sub, lengths, cfg)
        x = x + y
        cache = {**cache, "k": sub["k"], "v": sub["v"]}
        hx = apply_norm(p["ln_x"], x, cfg.norm_eps)
        x = x + A.cross_attn_cached(p["xattn"], hx, cache["mk"], cache["mv"], cfg)
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, _ = MOE.apply_moe(p["moe"], h2, cfg)
            x = x + y
        else:
            x = x + apply_mlp(p["mlp"], h2)
    return x, cache


def apply_layer_prefill(p: dict, x, cfg: ModelConfig, spec: LayerSpec, *,
                        memory=None, cache_len: int = 0):
    """Like train, but also returns the layer's decode cache."""
    mixer, ffn = spec
    B, S, _ = x.shape
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    cache: dict = {}
    if mixer in ("attn", "enc_attn", "attn_cross"):
        causal = mixer != "enc_attn"
        q, k, v = A.qkv_proj(p["attn"], h, cfg)
        if cfg.rope_theta > 0 and causal:
            pos = jnp.arange(S)[None]
            cos, sin = A.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
            q, k = A.apply_rope(q, cos, sin), A.apply_rope(k, cos, sin)
        out = A.chunked_attention(q, k, v, causal=causal,
                                  window=cfg.sliding_window,
                                  softcap=cfg.attn_logit_softcap)
        from repro.quant_runtime import qlinear
        x = x + qlinear.matmul(out.reshape(B, S, -1), p["attn"]["wo"])
        r = _kv_eff(cfg) // cfg.n_kv_heads
        if r > 1:  # repeat-sharded cache layout
            k = jnp.repeat(k, r, axis=2)
            v = jnp.repeat(v, r, axis=2)
        cdt = _cache_dtype()
        sc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        ck = jnp.zeros((B, sc, _kv_eff(cfg), cfg.resolved_head_dim), cdt)
        cv = jnp.zeros_like(ck)
        if cfg.sliding_window and sc == cfg.sliding_window:
            n = min(S, sc)
            positions = jnp.arange(S - n, S)
            slots = positions % sc
            ck = ck.at[:, slots].set(k[:, S - n:].astype(cdt))
            cv = cv.at[:, slots].set(v[:, S - n:].astype(cdt))
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k[:, :sc].astype(cdt), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v[:, :sc].astype(cdt), 0, axis=1)
        cache["k"], cache["v"] = ck, cv
        if mixer == "attn_cross":
            hx = apply_norm(p["ln_x"], x, cfg.norm_eps)
            x = x + A.cross_attn(p["xattn"], hx, memory, cfg)
            mk, mv = A.precompute_cross_kv(p["xattn"], memory, cfg)
            if r > 1:
                mk = jnp.repeat(mk, r, axis=2)
                mv = jnp.repeat(mv, r, axis=2)
            cache["mk"], cache["mv"] = (mk.astype(_cache_dtype()),
                                        mv.astype(_cache_dtype()))
    elif mixer == "mamba":
        y, cache = SSM.mamba_forward(p["mamba"], x, h, cfg, cache=None)
        x = x + y
    elif mixer == "cross":
        x = x + A.cross_attn(p["cross"], h, memory, cfg)
        mk, mv = A.precompute_cross_kv(p["cross"], memory, cfg)
        rx = _kv_eff(cfg) // cfg.n_kv_heads
        if rx > 1:
            mk = jnp.repeat(mk, rx, axis=2)
            mv = jnp.repeat(mv, rx, axis=2)
        cache = {"mk": mk.astype(_cache_dtype()),
                 "mv": mv.astype(_cache_dtype())}
    if ffn != "none":
        h2 = apply_norm(p["ln2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, _ = MOE.apply_moe(p["moe"], h2, cfg)
            x = x + y
        else:
            x = x + apply_mlp(p["mlp"], h2)
    return x, cache


# ---------------------------------------------------------------------------
# Stacked-period scans
# ---------------------------------------------------------------------------

def _init_stack(key, cfg, specs, n: int, dtype):
    """Stacked params: {"L{i}": leaf[n, ...]} via vmapped per-layer init."""
    def one_period(k):
        kk = split(k, len(specs))
        return {f"L{i}": init_layer(kk[i], cfg, specs[i], dtype)
                for i in range(len(specs))}
    return jax.vmap(one_period)(jax.random.split(key, n))


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)  # "full"


def run_stack_train(stack, x, cfg, specs, *, memory=None, remat="full"):
    from repro.runtime import flags, residual_constraint

    def body(carry, lp):
        h, aux = carry
        h = residual_constraint(h)
        for i, spec in enumerate(specs):
            h, a = apply_layer_train(lp[f"L{i}"], h, cfg, spec, memory=memory)
            aux = aux + a
        h = residual_constraint(h)
        return (h, aux), None

    if flags["unroll_layers"]:  # eager per-layer walk (calibration/debug)
        n = jax.tree.leaves(stack)[0].shape[0]
        carry = (x, jnp.float32(0.0))
        for t in range(n):
            carry, _ = body(carry, jax.tree.map(lambda l: l[t], stack))
        return carry
    (x, aux), _ = jax.lax.scan(_remat(body, remat), (x, jnp.float32(0.0)), stack)
    return x, aux


def run_stack_decode(stack, cache, x, lengths, cfg, specs):
    def body(h, xs):
        lp, lc = xs
        nc = {}
        for i, spec in enumerate(specs):
            h, nci = apply_layer_decode(lp[f"L{i}"], h, lc[f"L{i}"], lengths,
                                        cfg, spec)
            nc[f"L{i}"] = nci
        return h, nc
    x, new_cache = jax.lax.scan(body, x, (stack, cache))
    return x, new_cache


def run_stack_decode_paged(stack, cache, x, pctx, cfg, specs):
    """Paged decode scan: the write targets / block table in ``pctx`` are
    shared by every layer (all layers advance in lockstep), so they ride
    the closure instead of the scanned xs."""
    def body(h, xs):
        lp, lc = xs
        nc = {}
        for i, spec in enumerate(specs):
            h, nci = apply_layer_decode_paged(lp[f"L{i}"], h, lc[f"L{i}"],
                                              pctx, cfg, spec)
            nc[f"L{i}"] = nci
        return h, nc
    x, new_cache = jax.lax.scan(body, x, (stack, cache))
    return x, new_cache


def run_stack_prefill_paged(stack, cache, x, pctx, cfg, specs):
    """Prefill-chunk scan over the period stack (chunked prefill rides the
    decode dispatch, so this mirrors :func:`run_stack_decode_paged`)."""
    def body(h, xs):
        lp, lc = xs
        nc = {}
        for i, spec in enumerate(specs):
            h, nci = apply_layer_prefill_paged(lp[f"L{i}"], h, lc[f"L{i}"],
                                               pctx, cfg, spec)
            nc[f"L{i}"] = nci
        return h, nc
    x, new_cache = jax.lax.scan(body, x, (stack, cache))
    return x, new_cache


def run_stack_verify_paged(stack, cache, x, pctx, cfg, specs):
    """Speculative-verify scan over the period stack (mirrors
    :func:`run_stack_decode_paged`: write targets / table ride the
    closure since all layers advance in lockstep)."""
    def body(h, xs):
        lp, lc = xs
        nc = {}
        for i, spec in enumerate(specs):
            h, nci = apply_layer_verify_paged(lp[f"L{i}"], h, lc[f"L{i}"],
                                              pctx, cfg, spec)
            nc[f"L{i}"] = nci
        return h, nc
    x, new_cache = jax.lax.scan(body, x, (stack, cache))
    return x, new_cache


def run_stack_prefill(stack, x, cfg, specs, *, memory=None, cache_len=0):
    def body(h, lp):
        caches = {}
        for i, spec in enumerate(specs):
            h, c = apply_layer_prefill(lp[f"L{i}"], h, cfg, spec,
                                       memory=memory, cache_len=cache_len)
            caches[f"L{i}"] = c
        return h, caches
    x, cache = jax.lax.scan(body, x, stack)
    return x, cache


def _stack_cache(cfg, specs, n, batch, cache_len, mem_len=0):
    one = {f"L{i}": init_layer_cache(cfg, specs[i], batch, cache_len, mem_len)
           for i in range(len(specs))}
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), one)


def _stack_cache_paged(cfg, specs, n, batch, num_blocks, block_size):
    one = {f"L{i}": init_layer_cache_paged(cfg, specs[i], batch, num_blocks,
                                           block_size)
           for i in range(len(specs))}
    return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), one)


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable            # (params, batch, remat=) -> (loss, metrics)
    init_cache: Callable         # (batch, cache_len, **kw) -> cache
    prefill: Callable            # (params, batch, cache_len=, lengths=) ->
                                 #   (logits_last, cache); ``lengths`` [B]
                                 #   marks per-row true lengths of a
                                 #   right-padded batch (engine prefill)
    decode_step: Callable        # (params, tokens, cache) -> (logits, cache)
    init_paged_cache: Callable | None = None
                                 # (batch, cache_len, block_size=,
                                 #  num_blocks=) -> paged cache
    decode_step_paged: Callable | None = None
                                 # (params, tokens, paged cache, cow=) ->
                                 #   (logits, paged cache)
    prefill_chunk_paged: Callable | None = None
                                 # (params, tokens [B,C], paged cache,
                                 #  start [B], valid [B]) ->
                                 #   (last-valid-row logits [B,V], cache)
    verify_chunk_paged: Callable | None = None
                                 # (params, tokens [B,C], paged cache,
                                 #  start [B], valid [B]) ->
                                 #   (all-row logits [B,C,V], cache);
                                 #   row j bitwise-mirrors a decode step at
                                 #   position start+j (speculative verify)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    dtype = jnp.dtype(cfg.dtype)
    prefix_specs, n_prefix, specs, n_periods = layer_plan(cfg)

    def init(key):
        ks = split(key, 4)
        p = {"embed": init_embed(ks[0], cfg, dtype),
             "stack": _init_stack(ks[1], cfg, specs, n_periods, dtype),
             "final_norm": init_norm(cfg, dtype)}
        if n_prefix:
            p["prefix"] = _init_stack(ks[2], cfg, prefix_specs, n_prefix, dtype)
        return p

    def _memory(params, batch):
        if cfg.family == "vlm":
            return batch["image_embeds"].astype(ACT_DTYPE)
        return None

    def loss_fn(params, batch, remat: str = "full"):
        x = embed_tokens(params["embed"], batch["tokens"])
        mem = _memory(params, batch)
        aux = jnp.float32(0.0)
        if n_prefix:
            x, a = run_stack_train(params["prefix"], x, cfg, prefix_specs,
                                   memory=mem, remat=remat)
            aux = aux + a
        x, a = run_stack_train(params["stack"], x, cfg, specs, memory=mem,
                               remat=remat)
        aux = aux + a
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        loss, acc, n_tok = chunked_xent(params["embed"], x, batch["labels"])
        total = loss + aux
        return total, {"loss": loss, "aux_loss": aux, "accuracy": acc,
                       "n_tokens": n_tok}

    def init_cache(batch, cache_len, mem_len: int = 0):
        if cfg.family == "vlm":
            mem_len = mem_len or cfg.n_image_tokens
        c = {"stack": _stack_cache(cfg, specs, n_periods, batch, cache_len,
                                   mem_len),
             "lengths": jnp.zeros((batch,), jnp.int32)}
        if n_prefix:
            c["prefix"] = _stack_cache(cfg, prefix_specs, n_prefix, batch,
                                       cache_len, mem_len)
        return c

    def prefill(params, batch, cache_len: int | None = None, lengths=None):
        """``lengths`` [B]: per-row true lengths of a right-padded batch.
        Exact for dense causal-attention stacks (pad rows never feed real
        rows); ring (SWA) caches and Mamba state are position-keyed and MoE
        capacity routing couples tokens, so callers must pass equal-length
        batches there (the engine does)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache_len = cache_len or S
        x = embed_tokens(params["embed"], tokens)
        mem = _memory(params, batch)
        cache: dict = {}
        if n_prefix:
            x, cache["prefix"] = run_stack_prefill(
                params["prefix"], x, cfg, prefix_specs, memory=mem,
                cache_len=cache_len)
        x, cache["stack"] = run_stack_prefill(
            params["stack"], x, cfg, specs, memory=mem, cache_len=cache_len)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = last_token_logits(params["embed"], x, lengths)
        cache["lengths"] = (jnp.full((B,), S, jnp.int32) if lengths is None
                            else lengths.astype(jnp.int32))
        return logits, cache

    def decode_step(params, tokens, cache):
        """tokens [B, 1] -> (logits [B, V], new cache)."""
        x = embed_tokens(params["embed"], tokens)
        lengths = cache["lengths"]
        new_cache = dict(cache)
        if n_prefix:
            x, new_cache["prefix"] = run_stack_decode(
                params["prefix"], cache["prefix"], x, lengths, cfg,
                prefix_specs)
        x, new_cache["stack"] = run_stack_decode(
            params["stack"], cache["stack"], x, lengths, cfg, specs)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x)[:, 0]
        new_cache["lengths"] = lengths + 1
        return logits, new_cache

    # first attention position in the period (None for pure-SSM stacks);
    # families with a prefix stack (moe first_k_dense) always have attn in
    # the period too, so the stack leaf is a sufficient geometry probe
    _attn_idx = next((i for i, s in enumerate(specs)
                      if s[0] in ("attn", "enc_attn")), None)

    def init_paged_cache(batch, cache_len, *, block_size: int,
                         num_blocks: int):
        """Paged decode cache: block pools + shared table + free-list.

        SWA stacks page the *ring* (capacity = window), so ``cache_len``
        must cover the window and ``block_size`` must divide it — otherwise
        ring positions (``pos % window``) would straddle the block grid.
        """
        from repro.engine.paged import init_block_state
        window = cfg.sliding_window
        if window:
            if cache_len < window:
                raise ValueError(
                    f"paged SWA cache needs cache_len >= sliding_window "
                    f"({cache_len} < {window})")
            if window % block_size:
                raise ValueError(
                    f"block_size {block_size} must divide the sliding "
                    f"window {window} (ring positions are block-mapped)")
            mb = window // block_size
        else:
            mb = -(-cache_len // block_size)
        c = {"stack": _stack_cache_paged(cfg, specs, n_periods, batch,
                                         num_blocks, block_size),
             "lengths": jnp.zeros((batch,), jnp.int32),
             **init_block_state(batch, mb, num_blocks)}
        if n_prefix:
            c["prefix"] = _stack_cache_paged(cfg, prefix_specs, n_prefix,
                                             batch, num_blocks, block_size)
        return c

    def decode_step_paged(params, tokens, pcache, cow: bool = False):
        """tokens [B, 1] -> (logits [B, V], new paged cache).  Block
        allocation and write targets are computed once per step and shared
        by every attention layer (the stack advances in lockstep).  With
        ``cow=True`` (refcounted prefix caching) a write landing in a
        shared block pops a private copy first — see engine/paged.py."""
        from repro.engine.paged import BSTATE_KEYS, alloc_step
        x = embed_tokens(params["embed"], tokens)
        lengths = pcache["lengths"]
        new_cache = dict(pcache)
        if _attn_idx is not None:
            leaf = pcache["stack"][f"L{_attn_idx}"]["pk"]
            bs = leaf.shape[2]
            cap = pcache["tbl"].shape[1] * bs
            ring = bool(cfg.sliding_window) and cap == cfg.sliding_window
            bstate = {k: pcache[k] for k in BSTATE_KEYS}
            bstate, wblk, woff, cow_src = alloc_step(bstate, lengths, bs,
                                                     cap, ring, cow=cow)
            pctx = {"lengths": lengths, "tbl": bstate["tbl"],
                    "wblk": wblk, "woff": woff}
            if cow:
                pctx["cow_src"] = cow_src
                pctx["cow_any"] = jnp.any(cow_src != wblk)
            new_cache.update(bstate)
        else:  # pure-SSM stack: contiguous state, no pools to manage
            pctx = {"lengths": lengths}
        if n_prefix:
            x, new_cache["prefix"] = run_stack_decode_paged(
                params["prefix"], pcache["prefix"], x, pctx, cfg,
                prefix_specs)
        x, new_cache["stack"] = run_stack_decode_paged(
            params["stack"], pcache["stack"], x, pctx, cfg, specs)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x)[:, 0]
        new_cache["lengths"] = lengths + 1
        return logits, new_cache

    def prefill_chunk_paged(params, tokens, pcache, start, valid,
                            shared_until=None):
        """One prefill chunk through the paged cache (chunked prefill /
        prefix-hit tail recompute).  ``tokens`` [B, C] are rows
        ``start[b]..start[b]+valid[b]-1`` of each slot's prompt (``valid[b]
        == 0`` passes the slot through untouched); ``shared_until`` [B]
        marks each slot's prefix-hit watermark (rows below it write into
        shared blocks and are dropped — the cached rows are identical).
        Returns the logits of each slot's last valid row (garbage where
        ``valid == 0``) and the cache with the chunk's KV written and
        per-slot lengths advanced to ``start + valid``."""
        from repro.engine.paged import BSTATE_KEYS, span_targets
        B, C = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        start = start.astype(jnp.int32)
        valid = valid.astype(jnp.int32)
        pctx = {"start": start, "valid": valid}
        new_cache = dict(pcache)
        if _attn_idx is not None:
            leaf = pcache["stack"][f"L{_attn_idx}"]["pk"]
            bs = leaf.shape[2]
            cap = pcache["tbl"].shape[1] * bs
            ring = bool(cfg.sliding_window) and cap == cfg.sliding_window
            bstate = {k: pcache[k] for k in BSTATE_KEYS}
            wblk, woff = span_targets(bstate, start, valid, C, bs, cap,
                                      ring, shared_until)
            pctx.update(tbl=bstate["tbl"], wblk=wblk, woff=woff)
        if n_prefix:
            x, new_cache["prefix"] = run_stack_prefill_paged(
                params["prefix"], pcache["prefix"], x, pctx, cfg,
                prefix_specs)
        x, new_cache["stack"] = run_stack_prefill_paged(
            params["stack"], pcache["stack"], x, pctx, cfg, specs)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        idx = jnp.clip(valid - 1, 0, C - 1)
        xg = x[jnp.arange(B), idx][:, None]
        logits = lm_logits(params["embed"], xg)[:, 0]
        new_cache["lengths"] = jnp.where(valid > 0, start + valid,
                                         pcache["lengths"])
        return logits, new_cache

    def verify_chunk_paged(params, tokens, pcache, start, valid):
        """Speculative-verify forward: consume ``tokens`` [B, C] (rows
        ``start[b]..start[b]+valid[b]-1`` of each slot's continuation) and
        return the logits of **every** row [B, C, V] plus the cache with
        the rows' KV written and SSM state advanced by ``valid[b]`` steps.
        Row ``j``'s logits bitwise-mirror what ``decode_step_paged`` would
        have produced after consuming rows ``< j`` (attention runs the
        decode softmax over the gathered table, SSM the exact per-token
        recurrence), which is what makes greedy speculative decoding
        token-exact against the non-speculative engine (engine/spec.py).
        ``valid[b] == 0`` passes the slot through untouched; rows at or
        beyond ``valid[b]`` are state no-ops with garbage logits."""
        from repro.engine.paged import BSTATE_KEYS, span_targets
        B, C = tokens.shape
        x = embed_tokens(params["embed"], tokens)
        start = start.astype(jnp.int32)
        valid = valid.astype(jnp.int32)
        pctx = {"start": start, "valid": valid}
        new_cache = dict(pcache)
        if _attn_idx is not None:
            leaf = pcache["stack"][f"L{_attn_idx}"]["pk"]
            bs = leaf.shape[2]
            cap = pcache["tbl"].shape[1] * bs
            ring = bool(cfg.sliding_window) and cap == cfg.sliding_window
            bstate = {k: pcache[k] for k in BSTATE_KEYS}
            wblk, woff = span_targets(bstate, start, valid, C, bs, cap,
                                      ring)
            pctx.update(tbl=bstate["tbl"], wblk=wblk, woff=woff)
        if n_prefix:
            x, new_cache["prefix"] = run_stack_verify_paged(
                params["prefix"], pcache["prefix"], x, pctx, cfg,
                prefix_specs)
        x, new_cache["stack"] = run_stack_verify_paged(
            params["stack"], pcache["stack"], x, pctx, cfg, specs)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x)
        new_cache["lengths"] = jnp.where(valid > 0, start + valid,
                                         pcache["lengths"])
        return logits, new_cache

    return Model(cfg, init, loss_fn, init_cache, prefill, decode_step,
                 init_paged_cache=init_paged_cache,
                 decode_step_paged=decode_step_paged,
                 prefill_chunk_paged=prefill_chunk_paged,
                 verify_chunk_paged=verify_chunk_paged)


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t): frames (stub frontend) -> text
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)
    enc_specs = [("enc_attn", "mlp")]
    dec_specs = [("attn_cross", "mlp")]

    def init(key):
        ks = split(key, 5)
        return {
            "embed": init_embed(ks[0], cfg, dtype),
            "enc_stack": _init_stack(ks[1], cfg, enc_specs, cfg.n_enc_layers,
                                     dtype),
            "enc_norm": init_norm(cfg, dtype),
            "stack": _init_stack(ks[2], cfg, dec_specs, cfg.n_dec_layers,
                                 dtype),
            "final_norm": init_norm(cfg, dtype),
        }

    def encode(params, frames, remat="full"):
        x = frames.astype(ACT_DTYPE)
        x, _ = run_stack_train(params["enc_stack"], x, cfg, enc_specs,
                               remat=remat)
        return apply_norm(params["enc_norm"], x, cfg.norm_eps)

    def loss_fn(params, batch, remat: str = "full"):
        mem = encode(params, batch["frames"], remat)
        x = embed_tokens(params["embed"], batch["tokens"])
        x, _ = run_stack_train(params["stack"], x, cfg, dec_specs,
                               memory=mem, remat=remat)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        loss, acc, n_tok = chunked_xent(params["embed"], x, batch["labels"])
        return loss, {"loss": loss, "aux_loss": jnp.float32(0.0),
                      "accuracy": acc, "n_tokens": n_tok}

    def init_cache(batch, cache_len, mem_len: int = 0):
        mem_len = mem_len or cfg.enc_frames_cap
        return {"stack": _stack_cache(cfg, dec_specs, cfg.n_dec_layers, batch,
                                      cache_len, mem_len),
                "lengths": jnp.zeros((batch,), jnp.int32)}

    def prefill(params, batch, cache_len: int | None = None, lengths=None):
        mem = encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache_len = cache_len or S
        x = embed_tokens(params["embed"], tokens)
        x, cache = run_stack_prefill(params["stack"], x, cfg, dec_specs,
                                     memory=mem, cache_len=cache_len)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = last_token_logits(params["embed"], x, lengths)
        return logits, {"stack": cache,
                        "lengths": (jnp.full((B,), S, jnp.int32)
                                    if lengths is None
                                    else lengths.astype(jnp.int32))}

    def decode_step(params, tokens, cache):
        x = embed_tokens(params["embed"], tokens)
        lengths = cache["lengths"]
        x, new_stack = run_stack_decode(params["stack"], cache["stack"], x,
                                        lengths, cfg, dec_specs)
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x)[:, 0]
        return logits, {"stack": new_stack, "lengths": lengths + 1}

    return Model(cfg, init, loss_fn, init_cache, prefill, decode_step)
