"""Mixture-of-Experts FFN: grouped top-k routing with capacity (GShard form).

Tokens are reshaped into groups [G, g, D]; routing produces dispatch /
combine tensors [G, g, E, C] (C = per-group expert capacity), and expert
compute is three big einsums over stacked expert weights [E, D, F] — the
TPU-native formulation: everything is an MXU matmul, the expert axis shards
cleanly over the ``model`` mesh axis (EP), and groups shard over ``data``.

Top-k gates are renormalized over the selected experts (Mixtral convention).
Tokens overflowing capacity are dropped (their combine weight is zero — the
residual connection carries them through unchanged).  The load-balancing
auxiliary loss follows Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split
from repro.quant_runtime import qlinear


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split(key, 5)

    def expert_stack(k, din, dout):
        kk = jax.random.split(k, E)
        return jax.vmap(lambda kx: dense_init(kx, din, dout, dtype))(kk)

    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # fp32, never quantized
        "w_gate": expert_stack(ks[1], D, F),             # [E, D, F]
        "w_up": expert_stack(ks[2], D, F),
        "w_down": expert_stack(ks[3], F, D),             # [E, F, D]
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        kk = split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kk[0], D, Fs, dtype),
                       "w_up": dense_init(kk[1], D, Fs, dtype),
                       "w_down": dense_init(kk[2], Fs, D, dtype)}
    return p


def _group_tokens(T: int, target: int = 1024) -> int:
    """Largest group size <= target that divides T (prefer powers of two)."""
    g = min(T, target)
    while T % g:
        g -= 1
    return g


def top_k_routing(logits: jnp.ndarray, top_k: int, capacity: int):
    """logits [G, g, E] fp32 -> (dispatch [G,g,E,C] bool-ish, combine fp32,
    aux_loss scalar).  Sequential greedy top-k with per-expert positions."""
    G, g, E = logits.shape
    probs = jax.nn.softmax(logits, axis=-1)

    masks, gates = [], []
    p = probs
    for _ in range(top_k):
        idx = jnp.argmax(p, axis=-1)
        m = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # [G,g,E]
        gates.append(jnp.sum(probs * m, axis=-1))           # raw prob
        masks.append(m)
        p = p * (1.0 - m)

    # renormalize gates over the selected experts
    denom = jnp.maximum(sum(gates), 1e-9)
    gates = [gv / denom for gv in gates]

    # position of each token within its expert queue (across the k choices)
    combine = jnp.zeros((G, g, E, capacity), jnp.float32)
    prev_count = jnp.zeros((G, 1, E), jnp.float32)
    for m, gv in zip(masks, gates):
        pos_in_e = jnp.cumsum(m, axis=1) - m + prev_count    # [G,g,E]
        prev_count = prev_count + jnp.sum(m, axis=1, keepdims=True)
        pos = jnp.sum(pos_in_e * m, axis=-1)                 # [G,g]
        within = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # [G,g,C]
        combine = combine + (gv[..., None, None] * m[..., None]
                             * within[:, :, None, :])

    dispatch = (combine > 0).astype(jnp.bfloat16)

    # Switch-style load balance loss
    frac_tokens = jnp.mean(masks[0], axis=1)                 # [G, E]
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return dispatch, combine.astype(jnp.bfloat16), aux


def apply_moe(p: dict, x: jnp.ndarray, cfg: ModelConfig,
              group_target: int = 0, full_capacity: bool = False):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar).

    ``full_capacity`` sizes every expert queue for the worst case
    (``g * K`` — no token can ever be dropped).  Routing then decouples
    across tokens: each token's output is a pure function of its own
    hidden state, which the paged prefill-chunk path needs — its batch
    mixes unrelated slots' rows and pad garbage, and capacity competition
    against those would break prefill-order invariance."""
    from repro.runtime import flags
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    g = _group_tokens(T, group_target or flags["moe_group"])
    G = T // g
    cap = g * K if full_capacity \
        else max(int(g * K * cfg.capacity_factor / E), 1)
    # round capacity to a multiple of 8 for lane alignment
    cap = -(-cap // 8) * 8

    xt = x.reshape(G, g, D)
    router_w = qlinear.resolve(p["router"])
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    dispatch, combine, aux = top_k_routing(logits, K, cap)

    # dispatch tokens -> [E, G, C, D]
    ex_in = jnp.einsum("gsec,gsd->egcd", dispatch, xt)
    ex_in = ex_in.reshape(E, G * cap, D)

    wg = qlinear.resolve(p["w_gate"]).astype(x.dtype)
    wu = qlinear.resolve(p["w_up"]).astype(x.dtype)
    wd = qlinear.resolve(p["w_down"]).astype(x.dtype)
    h_g = jnp.einsum("etd,edf->etf", ex_in, wg)
    h_u = jnp.einsum("etd,edf->etf", ex_in, wu)
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    ex_out = jnp.einsum("etf,efd->etd", h, wd)

    y = jnp.einsum("gsec,egcd->gsd", combine,
                   ex_out.reshape(E, G, cap, D).astype(jnp.bfloat16))
    y = y.reshape(B, S, D).astype(x.dtype)

    if "shared" in p:
        from repro.models.common import apply_mlp
        y = y + apply_mlp(p["shared"], x)
    return y, aux * cfg.router_aux_loss
