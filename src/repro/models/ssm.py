"""Mamba-2 (SSD — state-space duality) block: chunked train/prefill scan and
constant-memory recurrent decode.

The SSD formulation computes the selective-state-space recurrence

    h_t = a_t * h_{t-1} + dt_t * x_t B_t^T          (h: [P, N] per head)
    y_t = C_t h_t + D * x_t

in matmul form: the sequence is split into chunks of length Q; within a
chunk the output is a masked (C_t . B_s) "attention" matmul, and a single
[P, N] state per chunk carries the recurrence across chunks via
``lax.scan``.  This keeps all heavy ops as MXU-shaped matmuls (the reason
SSD exists) and gives O(S * Q) memory instead of O(S^2).

TP note: the canonical Mamba-2 fuses [z | x | B | C | dt] into one
``in_proj``.  We keep them as **separate projections** so the head-structured
components (z, x, dt — all multiples of n_heads) shard cleanly over the
``model`` mesh axis while the head-shared B/C (ngroups=1) stay replicated;
the math is identical, and tensor parallelism needs no halo exchange on the
fused dim.  (Recorded in DESIGN.md §Hardware-adaptation.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split
from repro.quant_runtime import qlinear


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    di, N, nh = cfg.resolved_d_inner, cfg.ssm_state, cfg.n_ssm_heads
    K = cfg.conv_kernel
    ks = split(key, 7)
    return {
        "in_z": dense_init(ks[0], D, di, dtype),
        "in_x": dense_init(ks[1], D, di, dtype),
        "in_bc": dense_init(ks[2], D, 2 * N, dtype),
        "in_dt": dense_init(ks[3], D, nh, dtype),
        "conv_x_w": (0.1 * jax.random.normal(ks[4], (K, di), jnp.float32)
                     ).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": (0.1 * jax.random.normal(ks[5], (K, 2 * N), jnp.float32)
                      ).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(a_log) = -1
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[6], di, D, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None, valid=None):
    """Depthwise causal conv over S.  x [B,S,C]; w [K,C].

    ``state`` [B,K-1,C] prepends history (decode/prefill continuation).
    ``valid`` [B] marks per-row true lengths of a right-padded chunk: the
    returned state is then the history as of row ``valid[b]`` (pad rows
    must not enter the recurrence — chunked prefill).
    Returns (silu(out) [B,S,C] fp32, new_state [B,K-1,C]).
    """
    K = w.shape[0]
    Bsz, S, C = x.shape
    if state is None:
        state = jnp.zeros((Bsz, K - 1, C), x.dtype)
    ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B,S+K-1,C]
    out = jnp.zeros((Bsz, S, C), jnp.float32)
    for k in range(K):  # K is 4: unrolled taps, XLA fuses into one pass
        out = out + ext[:, k: k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    if valid is None:
        new_state = ext[:, S:]
    else:  # ext row (K-1) + t holds input position t
        idx = valid[:, None] + jnp.arange(K - 1)[None]
        new_state = jnp.take_along_axis(ext, idx[..., None], axis=1)
    return jax.nn.silu(out), new_state


def _gated_norm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-5) -> jnp.ndarray:
    """RMSNormGated: norm(y * silu(z)) * scale (fp32 internals)."""
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(g * g, axis=-1, keepdims=True)
    return g * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)


def _project(p: dict, h: jnp.ndarray, cfg: ModelConfig,
             conv_state: dict | None = None, valid=None):
    """Shared front half: projections + conv + dt.  Returns
    (z, xh [B,S,nh,P] fp32, Bc, Cc, dt, new_conv_state)."""
    Bsz, S, _ = h.shape
    di, N, nh, P = (cfg.resolved_d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                    cfg.ssm_head_dim)
    z = qlinear.matmul(h, p["in_z"])
    xc = qlinear.matmul(h, p["in_x"])
    bc = qlinear.matmul(h, p["in_bc"])
    dt_raw = qlinear.matmul(h, p["in_dt"])
    cs_x = conv_state["conv_x"] if conv_state else None
    cs_bc = conv_state["conv_bc"] if conv_state else None
    xc, ns_x = _causal_conv(xc, p["conv_x_w"], p["conv_x_b"], cs_x, valid)
    bc, ns_bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], cs_bc, valid)
    xh = xc.reshape(Bsz, S, nh, P)
    Bc, Cc = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    new_state = {"conv_x": ns_x.astype(jnp.bfloat16),
                 "conv_bc": ns_bc.astype(jnp.bfloat16)}
    return z, xh, Bc, Cc, dt, new_state


# ---------------------------------------------------------------------------
# Chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------

def ssd_scan(xh, Bc, Cc, dt, a_log, chunk: int, h0=None):
    """Chunked SSD.  xh [B,S,nh,P], Bc/Cc [B,S,N], dt [B,S,nh] (post-softplus).

    Returns (y [B,S,nh,P] fp32, h_final [B,nh,P,N] fp32).
    """
    Bsz, S, nh, P = xh.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    A = -jnp.exp(a_log.astype(jnp.float32))            # [nh], negative
    la = A[None, None] * dt.astype(jnp.float32)        # log a_t  [B,S,nh]
    # chunk views, scan axis first
    xc = xh.reshape(Bsz, nc, Q, nh, P).transpose(1, 0, 2, 3, 4)
    bc = Bc.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3)
    cc = Cc.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3)
    dc = dt.reshape(Bsz, nc, Q, nh).transpose(1, 0, 2, 3)
    lc = la.reshape(Bsz, nc, Q, nh).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)

    def body(h, xs):
        xq, bq, cq, dq, lq = xs                        # per-chunk tensors
        xq = xq.astype(jnp.float32)
        bq = bq.astype(jnp.float32)
        cq = cq.astype(jnp.float32)
        cum = jnp.cumsum(lq, axis=1)                   # [B,Q,nh] inclusive
        # intra-chunk: scores[t,s] = (C_t.B_s) * exp(cum_t - cum_s) * dt_s, s<=t
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # [B,Q(t),Q(s),nh]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], seg, -jnp.inf))
        cb = jnp.einsum("btn,bsn->bts", cq, bq)        # [B,Q,Q]
        w = cb[..., None] * decay * dq[:, None, :, :]  # [B,t,s,nh]
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xq)
        # inter-chunk: contribution of h (state before this chunk)
        state_decay = jnp.exp(cum)                     # exp(sum_{r<=t} la_r)
        y_inter = jnp.einsum("btn,bhpn->bthp", cq, h) * state_decay[..., None]
        # chunk state update
        rem = jnp.exp(cum[:, -1:, :] - cum)            # decay from s to end
        contrib = jnp.einsum("bshp,bsn,bsh,bsh->bhpn", xq, bq, dq, rem)
        h_new = h * jnp.exp(cum[:, -1])[..., None, None] + contrib
        return h_new, y_intra + y_inter

    h_fin, ys = jax.lax.scan(body, h0, (xc, bc, cc, dc, lc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nc * Q, nh, P)
    return y[:, :S], h_fin


def mamba_forward(p: dict, x_in: jnp.ndarray, h: jnp.ndarray,
                  cfg: ModelConfig, cache: dict | None = None):
    """Full Mamba-2 sublayer on normed input ``h``; ``x_in`` is the residual
    source dtype reference.  Returns (out [B,S,D], new_cache or None)."""
    Bsz, S, _ = h.shape
    di = cfg.resolved_d_inner
    z, xh, Bc, Cc, dt, conv_state = _project(p, h, cfg, cache)
    h0 = cache["h"] if cache is not None else None
    y, h_fin = ssd_scan(xh, Bc, Cc, dt, p["a_log"], cfg.ssm_chunk, h0)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = _gated_norm(y.reshape(Bsz, S, di), z, p["norm_scale"])
    out = qlinear.matmul(y.astype(x_in.dtype), p["out_proj"])
    return out, {"h": h_fin, **conv_state}


def mamba_train(p: dict, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    out, _ = mamba_forward(p, h, h, cfg, cache=None)
    return out


def mamba_prefill_chunk(p: dict, x_in: jnp.ndarray, h: jnp.ndarray,
                        cfg: ModelConfig, cache: dict, valid: jnp.ndarray):
    """One prefill *chunk* through the Mamba-2 sublayer with state threading.

    ``h`` [B, C, D] holds rows at positions ``start..start+C-1`` of each
    slot's prompt; rows ``>= valid[b]`` are pads and are masked to **exact
    no-ops** of the SSD recurrence (``x = 0``, ``dt = 0`` post-softplus —
    the same zeros ``ssd_scan`` pads with internally), so the carried state
    and the real rows' outputs are bit-identical to the corresponding
    chunk of a one-shot :func:`mamba_forward` whenever chunk boundaries
    fall on multiples of ``cfg.ssm_chunk`` (the engine enforces
    ``chunk_size % ssm_chunk == 0`` for stacks with SSM layers).
    Slots with ``valid == 0`` pass their state through untouched.
    """
    Bsz, C, _ = h.shape
    di = cfg.resolved_d_inner
    z, xh, Bc, Cc, dt, conv_state = _project(p, h, cfg, cache, valid=valid)
    vm = jnp.arange(C)[None, :] < valid[:, None]            # [B, C]
    xh = jnp.where(vm[:, :, None, None], xh, 0.0)
    dt = jnp.where(vm[:, :, None], dt, 0.0)
    Bc = jnp.where(vm[:, :, None], Bc, 0.0)
    Cc = jnp.where(vm[:, :, None], Cc, 0.0)
    y, h_fin = ssd_scan(xh, Bc, Cc, dt, p["a_log"], cfg.ssm_chunk,
                        cache["h"])
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = _gated_norm(y.reshape(Bsz, C, di), z, p["norm_scale"])
    out = qlinear.matmul(y.astype(x_in.dtype), p["out_proj"])
    return out, {"h": h_fin, **conv_state}


def mamba_verify_chunk(p: dict, x_in: jnp.ndarray, h: jnp.ndarray,
                       cfg: ModelConfig, cache: dict, valid: jnp.ndarray):
    """Speculative-verify chunk: the **exact recurrence**, stepped row by
    row — a bitwise mirror of ``valid[b]`` successive :func:`mamba_decode`
    calls (same projections, same conv, same per-step ``h = a h + dt x B^T``
    update and einsum shapes), unlike :func:`mamba_prefill_chunk` whose SSD
    chunk math reorders the float ops.  The speculative engine needs its
    verifier logits (and the rolled-back state on rejection) to equal what
    per-token decode would have produced, so the chunk here trades the SSD
    matmul form for per-row decode parity; chunks are ``n_spec + 1`` rows,
    so the sequential scan stays cheap.

    Rows ``>= valid[b]`` are state no-ops (``dt = 0``: decay ``exp(0) = 1``,
    update ``0``); slots with ``valid == 0`` pass state and conv history
    through untouched.  Returns (out [B,C,D], new_cache) — output rows at
    and beyond ``valid[b]`` are garbage, callers must mask.
    """
    Bsz, C, _ = h.shape
    di = cfg.resolved_d_inner
    z, xh, Bc, Cc, dt, conv_state = _project(p, h, cfg, cache, valid=valid)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh32 = xh.astype(jnp.float32)
    vm = jnp.arange(C)[None, :] < valid[:, None]            # [B, C]
    dt_m = jnp.where(vm[:, :, None], dt, 0.0)

    def body(hst, xs):
        xt, bt, ct, dtt = xs                   # [B,nh,P] [B,N] [B,N] [B,nh]
        a = jnp.exp(A[None] * dtt)
        dBx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt.astype(jnp.float32), dtt)
        hst = hst * a[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), hst)
        return hst, y

    xs = (xh32.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2),
          Cc.transpose(1, 0, 2), dt_m.transpose(1, 0, 2))
    h_fin, ys = jax.lax.scan(body, cache["h"], xs)
    y = ys.transpose(1, 0, 2, 3)                            # [B,C,nh,P]
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh32
    y = _gated_norm(y.reshape(Bsz, C, di), z, p["norm_scale"])
    out = qlinear.matmul(y.astype(x_in.dtype), p["out_proj"])
    return out, {"h": h_fin, **conv_state}


def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    di, N, nh, P = (cfg.resolved_d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                    cfg.ssm_head_dim)
    return {
        "h": jnp.zeros((batch, nh, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.conv_kernel - 1, di), jnp.bfloat16),
        "conv_bc": jnp.zeros((batch, cfg.conv_kernel - 1, 2 * N),
                             jnp.bfloat16),
    }


def mamba_decode(p: dict, x: jnp.ndarray, cache: dict, cfg: ModelConfig):
    """One-token decode.  x [B,1,D].  Returns (y [B,1,D], new_cache).

    Uses the exact recurrence (no chunking) — one step of
    ``h = a h + dt x B^T; y = C h + D x``."""
    Bsz = x.shape[0]
    di = cfg.resolved_d_inner
    z, xh, Bc, Cc, dt, conv_state = _project(p, x, cfg, cache)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(A[None] * dt[:, 0])                    # [B,nh]
    xh32 = xh.astype(jnp.float32)
    dBx = jnp.einsum("bhp,bn,bh->bhpn", xh32[:, 0], Bc[:, 0].astype(jnp.float32),
                     dt[:, 0])
    h = cache["h"] * a[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), h)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh32[:, 0]
    y = _gated_norm(y.reshape(Bsz, 1, di), z, p["norm_scale"])
    out = qlinear.matmul(y.astype(x.dtype), p["out_proj"])
    return out, {"h": h, **conv_state}
