from repro.optim.adamw import (adamw_update, global_norm, init_opt_state,
                               lr_schedule)
from repro.optim.compress import compress_grads, init_error_state

__all__ = ["adamw_update", "global_norm", "init_opt_state", "lr_schedule",
           "compress_grads", "init_error_state"]
