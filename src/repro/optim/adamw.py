"""Pure-JAX AdamW with cosine schedule, global-norm clipping, and optional
8-bit (block-quantized) optimizer state — the large-scale memory trick that
makes trillion-parameter configs fit (see EXPERIMENTS.md kimi-k2 notes).

State layout per parameter leaf:
  fp32 mode : {"m": fp32, "v": fp32}
  int8 mode : {"m": int8, "m_scale": fp32[blocks], "v": int8, "v_scale": ...}
plus global {"step": int32}.

The int8 moments use symmetric per-block (size 256 along the flattened axis)
absmax quantization with dequant-update-requant each step — the classic
8-bit Adam recipe (Dettmers et al.) adapted to a functional JAX update.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

BLOCK = 256


# ---------------------------------------------------------------------------
# int8 moment (de)quantization
# ---------------------------------------------------------------------------

def _q8(x: jnp.ndarray):
    """fp32 -> (int8 [..., nb, BLOCK], fp32 scales [..., nb, 1]).

    Blocks along the LAST axis only, so quantized moments keep the
    parameter's leading layout and inherit its sharding (launch/sharding.py
    appends a replicated block axis to the param spec)."""
    L = x.shape[-1]
    nb = -(-L // BLOCK)
    pad = nb * BLOCK - L
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = x.reshape(*x.shape[:-1], nb, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    xb = q.astype(jnp.float32) * scale
    x = xb.reshape(*xb.shape[:-2], xb.shape[-2] * BLOCK)
    return x[..., : shape[-1]]


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

def lr_schedule(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# Init / update
# ---------------------------------------------------------------------------

def init_opt_state(params: Any, tc: TrainConfig) -> dict:
    """Moments (+ step) and, for non-fp32 parameter trees, an fp32 master
    copy: low-precision params round away updates near their resolution
    floor (bf16 has ~3 significant digits — an lr*1e-3 update against an
    O(0.1) weight is half rounding error), so the update accumulates in the
    master and params are just its cast."""
    int8 = tc.opt_state_dtype == "int8"

    def leaf_state(p):
        if int8:
            z = jnp.zeros(p.shape, jnp.float32)
            qm, sm = _q8(z)
            return {"m": qm, "m_scale": sm, "v": qm, "v_scale": sm}
        dt = jnp.dtype(tc.opt_state_dtype)
        return {"m": jnp.zeros(p.shape, dt), "v": jnp.zeros(p.shape, dt)}

    state = {"mu": jax.tree.map(leaf_state, params),
             "step": jnp.zeros((), jnp.int32)}
    if any(l.dtype != jnp.float32 for l in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params: Any, grads: Any, opt_state: dict, tc: TrainConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(tc, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if tc.grad_clip > 0 else jnp.float32(1.0)
    b1, b2 = tc.b1, tc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    int8 = tc.opt_state_dtype == "int8"

    def leaf_update(p, g, s, mw):
        g = g.astype(jnp.float32) * clip
        if int8:
            m = _dq8(s["m"], s["m_scale"], p.shape)
            # v is companded: int8 stores sqrt(v) — symmetric int8 directly
            # on v zeroes small second moments (rsqrt blow-up); the sqrt
            # compander keeps ~127 levels across v's usable dynamic range
            v = _dq8(s["v"], s["v_scale"], p.shape) ** 2
        else:
            m = s["m"].astype(jnp.float32)
            v = s["v"].astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + 1e-8)
        wd = tc.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/bias
        base = p.astype(jnp.float32) if mw is None else mw
        new_w = base - lr * (upd + wd * base)
        if int8:
            qm, sm = _q8(m)
            qv, sv = _q8(jnp.sqrt(v))
            new_s = {"m": qm, "m_scale": sm, "v": qv, "v_scale": sv}
        else:
            dt = s["m"].dtype
            new_s = {"m": m.astype(dt), "v": v.astype(dt)}
        return new_w.astype(p.dtype), new_s, new_w

    master = opt_state.get("master")
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = treedef.flatten_up_to(opt_state["mu"])
    flat_mw = (treedef.flatten_up_to(master) if master is not None
               else [None] * len(flat_p))
    out = [leaf_update(p, g, s, mw)
           for p, g, s, mw in zip(flat_p, flat_g, flat_s, flat_mw)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_state = {"mu": new_mu, "step": step}
    if master is not None:
        new_state["master"] = jax.tree_util.tree_unflatten(
            treedef, [o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, stats
