"""Gradient compression for the data-parallel all-reduce: int8 with error
feedback (EF-SGD style).

At 1000+ node scale the gradient all-reduce crosses DCN/ICI pod boundaries;
int8 compression cuts that traffic 4x (vs fp32) / 2x (vs bf16).  Error
feedback keeps the quantization bias out of the long-run trajectory: the
residual between the true gradient and its quantized form is added back
before the next step's quantization, so compression error is O(1) instead of
accumulating.

This module is algebra-only (quantize/dequantize + residual bookkeeping);
the actual collective stays a standard ``psum``/GSPMD all-reduce over the
int8 payload inside the jitted step (XLA all-reduces the dequantized fp
values; on real multi-host deployments the int8 tensor is what crosses the
wire via ``jax.lax.all_gather`` of packed payloads — see
``launch/train.py``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 512


def _q(x: jnp.ndarray):
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    flat = jnp.pad(flat, (0, nb * BLOCK - n)).reshape(nb, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, err: Any):
    """Returns (quant-dequant grads, new error state).

    The returned grads are what the all-reduce sees; adding the residual to
    ``err`` implements error feedback.
    """
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _q(g32)
        gq = _dq(q, s, g.shape)
        return gq.astype(g.dtype), g32 - gq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))
