"""End-to-end DAQ study: the paper's Tables 2-5 at CPU scale.

Protocol (mirrors paper §3.1, DESIGN.md §7):
  1. train a base LM on the plain bigram corpus           -> W_base
  2. SFT it on the stylized corpus at low LR              -> W_post
  3. quantize W_post under each setting; measure
       ΔW-L2 / SignRate / CosSim  (exact, from repro.quantize)
       Style / General            (rubric-proxy scores in [0, 2])

Every setting — AbsMax, DAQ x {mse, sign, cosine}, SmoothQuant, AWQ — runs
through the one public entry point ``repro.quantize.quantize``; the method
is selected by ``QuantConfig.method`` and calibration stats flow through
the registry's ``calibrate`` hook.  This module holds only study
orchestration (training, caching, eval, table emission).

Settings:
  Table 2: BF16 base, BF16 post, AbsMax fp8 (block/channel),
           SmoothQuant-fp8, AWQ-fp8 (per-channel, calibration-based)
  Table 3: MSE-guided scale search  x {block, channel} x 3 ranges
  Table 4: Sign-guided              x ...
  Table 5: Cosine-guided            x ...

Checkpoints are cached under ``experiments/study/`` so benchmark tables
re-run instantly; ``--retrain`` forces a fresh pair.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax

from repro.configs import ModelConfig, QuantConfig, TrainConfig
from repro.data import LanguageSpec, eval_scores
from repro.models import build_model
from repro.quantize import quantize

STUDY_DIR = "experiments/study"

# The study model: dense glm4-family at CPU scale, sized so the stylized
# behaviour is learnable yet the SFT delta stays small-magnitude (fragile
# under fp8 — the paper's regime).
STUDY_CFG = ModelConfig(
    name="study-dense", family="dense", n_layers=3, d_model=96,
    n_heads=6, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16,
    rope_theta=10000.0, source="study", notes="DAQ study model")

BASE_TC = TrainConfig(learning_rate=1e-3, warmup_steps=30, total_steps=600,
                      weight_decay=0.01, seed=0)
SFT_TC = TrainConfig(learning_rate=5e-4, warmup_steps=10, total_steps=350,
                     weight_decay=0.0, seed=1)
BATCH, SEQ = 16, 128

# Study quantization format.  At 100K-param scale E4M3's ~4% multiplicative
# noise cannot erase behaviour (toy weights lack the heavy-tailed outlier
# structure that makes fp8 destructive at 671B) — INT4 block-32 puts the
# study in the paper's fragile-delta regime (the severe-noise setting the
# paper itself proposes in §5).  fp8 rows are reported alongside in Table 2
# for reference.  See EXPERIMENTS.md §Tables for the measured pattern.
STUDY_FMT = "int4"
STUDY_BLOCK = 32


def language(cfg: ModelConfig = STUDY_CFG) -> LanguageSpec:
    # hard_style: the style also permutes the bigram table — a behaviour
    # distributed across many small weights (the paper's fragile regime),
    # unlike the low-rank marker pattern which survives any fp8 noise.
    return LanguageSpec(vocab=cfg.vocab_size, seed=1234, hard_style=True)


def prepare_models(*, retrain: bool = False, study_dir: str = STUDY_DIR,
                   base_steps: int | None = None,
                   sft_steps: int | None = None):
    """Returns (model, params_base, params_post), training if not cached."""
    from repro import checkpoint as ckpt
    from repro.launch.train import train_loop

    cfg = STUDY_CFG
    model = build_model(cfg)
    spec = language(cfg)
    base_dir = os.path.join(study_dir, "base")
    sft_dir = os.path.join(study_dir, "sft")

    base_tc = dataclasses.replace(
        BASE_TC, total_steps=base_steps or BASE_TC.total_steps)
    sft_tc = dataclasses.replace(
        SFT_TC, total_steps=sft_steps or SFT_TC.total_steps)

    if retrain:
        import shutil
        shutil.rmtree(study_dir, ignore_errors=True)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if ckpt.latest(base_dir) != base_tc.total_steps:
        print("[study] training base model...", flush=True)
        train_loop(model, base_tc, batch_size=BATCH, seq=SEQ,
                   steps=base_tc.total_steps, ckpt_dir=base_dir,
                   save_every=200, style=False, language=spec,
                   log_every=100)
    from repro.launch.steps import init_train_state
    state_shape = jax.eval_shape(
        lambda k: init_train_state(model, base_tc, k), jax.random.PRNGKey(0))
    base_state = ckpt.restore(base_dir, ckpt.latest(base_dir), state_shape)
    params_base = base_state["params"]

    if ckpt.latest(sft_dir) != sft_tc.total_steps:
        print("[study] SFT on stylized corpus...", flush=True)
        train_loop(model, sft_tc, batch_size=BATCH, seq=SEQ,
                   steps=sft_tc.total_steps, ckpt_dir=sft_dir,
                   save_every=200, style="mixed", language=spec,
                   log_every=50, init_params=params_base)
    sft_state_shape = jax.eval_shape(
        lambda k: init_train_state(model, sft_tc, k), jax.random.PRNGKey(0))
    sft_state = ckpt.restore(sft_dir, ckpt.latest(sft_dir), sft_state_shape)
    params_post = sft_state["params"]
    return model, params_base, params_post


def evaluate(model, params, spec: LanguageSpec) -> dict:
    # 32x192 ~ 6k positions per corpus: score noise ~ +-0.01
    return eval_scores(model, params, spec, batch=32, seq=192, seed=999)


def quantize_and_eval(model, params_post, params_base, qcfg: QuantConfig,
                      spec: LanguageSpec) -> dict:
    """Quantize (method from ``qcfg.method``) and score; calibration-based
    methods collect activation stats through the registry's hook."""
    qparams, report = quantize(params_post, params_base, qcfg,
                               mode="dequant", out_dtype="float32",
                               model=model, spec=spec)
    scores = evaluate(model, qparams, spec)
    g = report.global_chosen
    return {
        "delta_l2": g["delta_l2"], "sign_rate": g["sign_rate"],
        "cosine": g["cosine"], "mse": g["mse"],
        "style": scores["style"], "general": scores["general"],
    }


# ---------------------------------------------------------------------------
# The tables
# ---------------------------------------------------------------------------

RANGES = [(0.5, 2.0), (0.8, 1.25), (0.9, 1.11)]


def run_tables(tables=("2", "3", "4", "5"), *, retrain: bool = False,
               out_path: str = os.path.join(STUDY_DIR, "tables.json"),
               extra_qcfg: dict | None = None) -> dict:
    model, params_base, params_post = prepare_models(retrain=retrain)
    spec = language()
    results: dict = json.load(open(out_path)) if os.path.exists(out_path) \
        else {}

    def put(table, row_name, row):
        results.setdefault(table, {})[row_name] = row
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        cols = " ".join(f"{k}={v:.4f}" for k, v in row.items()
                        if isinstance(v, float))
        print(f"[T{table}] {row_name:34s} {cols}", flush=True)

    kw = {"fmt": STUDY_FMT, "block_size": STUDY_BLOCK, **(extra_qcfg or {})}
    fmt_tag = kw["fmt"]

    if "2" in tables:
        if "base_bf16" not in results.get("2", {}):
            s = evaluate(model, params_base, spec)
            put("2", "base_bf16", {"style": s["style"],
                                   "general": s["general"]})
        if "post_bf16" not in results.get("2", {}):
            s = evaluate(model, params_post, spec)
            put("2", "post_bf16", {"style": s["style"], "general": s["general"],
                                   "delta_l2": 0.0, "sign_rate": 1.0,
                                   "cosine": 1.0})
        for gran in ("block", "channel"):
            for fmt in (fmt_tag, "fp8_e4m3"):
                name = f"absmax_{fmt}_{gran}"
                if name not in results.get("2", {}):
                    q = QuantConfig(**{**kw, "fmt": fmt,
                                       "granularity": gran,
                                       "method": "absmax"})
                    put("2", name, quantize_and_eval(
                        model, params_post, params_base, q, spec))
        for method in ("smoothquant", "awq"):
            name = f"{method}_{fmt_tag}_channel"
            if name not in results.get("2", {}):
                q = QuantConfig(**{**kw, "granularity": "channel",
                                   "method": method})
                put("2", name, quantize_and_eval(
                    model, params_post, params_base, q, spec))

    metric_tables = {"3": "mse", "4": "sign", "5": "cosine"}
    for t, metric in metric_tables.items():
        if t not in tables:
            continue
        for gran in ("block", "channel"):
            for (lo, hi) in RANGES:
                name = f"{metric}_{gran}_[{lo},{hi}]"
                if name in results.get(t, {}):
                    continue
                q = QuantConfig(metric=metric, granularity=gran,
                                alpha_min=lo, alpha_max=hi,
                                n_coarse=5, n_fine=10, **kw)
                put(t, name, quantize_and_eval(
                    model, params_post, params_base, q, spec))
    return results
