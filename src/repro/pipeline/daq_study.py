"""End-to-end DAQ study: the paper's Tables 2-5 at CPU scale.

Protocol (mirrors paper §3.1, DESIGN.md §7):
  1. train a base LM on the plain bigram corpus           -> W_base
  2. SFT it on the stylized corpus at low LR              -> W_post
  3. quantize W_post under each setting; measure
       ΔW-L2 / SignRate / CosSim  (exact, from quantize_tree)
       Style / General            (rubric-proxy scores in [0, 2])

Settings:
  Table 2: BF16 base, BF16 post, AbsMax fp8 (block/channel),
           SmoothQuant-fp8, AWQ-fp8 (per-channel, calibration-based)
  Table 3: MSE-guided scale search  x {block, channel} x 3 ranges
  Table 4: Sign-guided              x ...
  Table 5: Cosine-guided            x ...

Checkpoints are cached under ``experiments/study/`` so benchmark tables
re-run instantly; ``--retrain`` forces a fresh pair.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, QuantConfig, TrainConfig
from repro.core.daq import absmax_tree, quantize_tree
from repro.data import LanguageSpec, eval_scores
from repro.models import build_model

STUDY_DIR = "experiments/study"

# The study model: dense glm4-family at CPU scale, sized so the stylized
# behaviour is learnable yet the SFT delta stays small-magnitude (fragile
# under fp8 — the paper's regime).
STUDY_CFG = ModelConfig(
    name="study-dense", family="dense", n_layers=3, d_model=96,
    n_heads=6, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16,
    rope_theta=10000.0, source="study", notes="DAQ study model")

BASE_TC = TrainConfig(learning_rate=1e-3, warmup_steps=30, total_steps=600,
                      weight_decay=0.01, seed=0)
SFT_TC = TrainConfig(learning_rate=5e-4, warmup_steps=10, total_steps=350,
                     weight_decay=0.0, seed=1)
BATCH, SEQ = 16, 128

# Study quantization format.  At 100K-param scale E4M3's ~4% multiplicative
# noise cannot erase behaviour (toy weights lack the heavy-tailed outlier
# structure that makes fp8 destructive at 671B) — INT4 block-32 puts the
# study in the paper's fragile-delta regime (the severe-noise setting the
# paper itself proposes in §5).  fp8 rows are reported alongside in Table 2
# for reference.  See EXPERIMENTS.md §Tables for the measured pattern.
STUDY_FMT = "int4"
STUDY_BLOCK = 32


def language(cfg: ModelConfig = STUDY_CFG) -> LanguageSpec:
    # hard_style: the style also permutes the bigram table — a behaviour
    # distributed across many small weights (the paper's fragile regime),
    # unlike the low-rank marker pattern which survives any fp8 noise.
    return LanguageSpec(vocab=cfg.vocab_size, seed=1234, hard_style=True)


def prepare_models(*, retrain: bool = False, study_dir: str = STUDY_DIR,
                   base_steps: int | None = None,
                   sft_steps: int | None = None):
    """Returns (model, params_base, params_post), training if not cached."""
    from repro import checkpoint as ckpt
    from repro.launch.train import train_loop

    cfg = STUDY_CFG
    model = build_model(cfg)
    spec = language(cfg)
    base_dir = os.path.join(study_dir, "base")
    sft_dir = os.path.join(study_dir, "sft")

    base_tc = dataclasses.replace(
        BASE_TC, total_steps=base_steps or BASE_TC.total_steps)
    sft_tc = dataclasses.replace(
        SFT_TC, total_steps=sft_steps or SFT_TC.total_steps)

    if retrain:
        import shutil
        shutil.rmtree(study_dir, ignore_errors=True)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if ckpt.latest(base_dir) != base_tc.total_steps:
        print("[study] training base model...", flush=True)
        train_loop(model, base_tc, batch_size=BATCH, seq=SEQ,
                   steps=base_tc.total_steps, ckpt_dir=base_dir,
                   save_every=200, style=False, language=spec,
                   log_every=100)
    from repro.launch.steps import init_train_state
    state_shape = jax.eval_shape(
        lambda k: init_train_state(model, base_tc, k), jax.random.PRNGKey(0))
    base_state = ckpt.restore(base_dir, ckpt.latest(base_dir), state_shape)
    params_base = base_state["params"]

    if ckpt.latest(sft_dir) != sft_tc.total_steps:
        print("[study] SFT on stylized corpus...", flush=True)
        train_loop(model, sft_tc, batch_size=BATCH, seq=SEQ,
                   steps=sft_tc.total_steps, ckpt_dir=sft_dir,
                   save_every=200, style="mixed", language=spec,
                   log_every=50, init_params=params_base)
    sft_state_shape = jax.eval_shape(
        lambda k: init_train_state(model, sft_tc, k), jax.random.PRNGKey(0))
    sft_state = ckpt.restore(sft_dir, ckpt.latest(sft_dir), sft_state_shape)
    params_post = sft_state["params"]
    return model, params_base, params_post


def evaluate(model, params, spec: LanguageSpec) -> dict:
    # 32x192 ~ 6k positions per corpus: score noise ~ +-0.01
    return eval_scores(model, params, spec, batch=32, seq=192, seed=999)


def quantize_and_eval(model, params_post, params_base, qcfg: QuantConfig,
                      spec: LanguageSpec, *, absmax_only: bool = False) -> dict:
    fn = absmax_tree if absmax_only else quantize_tree
    qparams, report = fn(params_post, params_base, qcfg, mode="dequant",
                         out_dtype="float32")
    scores = evaluate(model, qparams, spec)
    g = report.global_chosen
    return {
        "delta_l2": g["delta_l2"], "sign_rate": g["sign_rate"],
        "cosine": g["cosine"], "mse": g["mse"],
        "style": scores["style"], "general": scores["general"],
    }


# ---------------------------------------------------------------------------
# SmoothQuant / AWQ baselines (weight-only, calibration-based equalization)
# ---------------------------------------------------------------------------

def collect_input_stats(model, params, spec: LanguageSpec,
                        n_batches: int = 2) -> list:
    """Eager unrolled forward; returns [(w_shape, absmax[in])] in call order."""
    from repro import runtime
    from repro.data.synthetic import _full_logits, sample_batch
    from repro.quant_runtime import qlinear

    runtime.flags["unroll_layers"] = True
    qlinear.RECORD = []
    try:
        for i in range(n_batches):
            toks = sample_batch(jax.random.PRNGKey(500 + i), spec, 4, 64)
            _full_logits(model, params,
                         {"tokens": toks[:, :-1], "labels": toks[:, 1:]})
        rec = qlinear.RECORD
    finally:
        qlinear.RECORD = None
        runtime.flags["unroll_layers"] = False
    # merge duplicate calls (same weight across batches) by call position
    per_call = len(rec) // n_batches
    merged = []
    for j in range(per_call):
        shapes = rec[j][0]
        amax = jnp.stack([rec[j + b * per_call][1]
                          for b in range(n_batches)]).max(0)
        merged.append((shapes, amax))
    return merged


def _equalize_quantize(params_post, params_base, stats: list,
                       qcfg: QuantConfig, *, mode: str) -> tuple:
    """SmoothQuant (fixed alpha=0.5) or AWQ (alpha grid by output MSE):
    quantize Q(W diag(s)) / diag(s) — numerically the same space as W, so
    delta metrics stay well-defined (a bonus over the paper's absorbed
    formulation)."""
    from repro.core.formats import get_format
    from repro.core.granularity import absmax_scale, apply_qdq
    from repro.core import metrics as M
    from repro.core.policy import path_str, should_quantize

    fmt = get_format(qcfg.fmt)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_post)
    base_leaves = jax.tree_util.tree_leaves(params_base)

    # match recorded stats to leaves by (in_dim, out_dim) queue per shape
    queues: dict[tuple, list] = {}
    for shape, amax in stats:
        queues.setdefault(shape, []).append(amax)

    out = []
    parts_c, parts_d = [], []
    for (path, wp), wb in zip(flat, base_leaves):
        name = path_str(path)
        if not should_quantize(name, wp, qcfg.skip_patterns):
            out.append(wp)
            continue
        wp32 = wp.astype(jnp.float32)
        wb32 = wb.astype(jnp.float32)
        dp = wp32 - wb32

        def qdq_scaled(w2d, s_vec):
            ws = w2d * s_vec[:, None]
            sc = absmax_scale(ws, qcfg.granularity, fmt, qcfg.block_size)
            return apply_qdq(ws, sc, qcfg.granularity, fmt,
                             qcfg.block_size) / s_vec[:, None]

        def leaf_2d(w2d, wb2d):
            in_dim = w2d.shape[0]
            key = tuple(w2d.shape)
            amax = queues.get(key, [None]).pop(0) if queues.get(key) else None
            if amax is None:
                amax = jnp.ones((in_dim,), jnp.float32)
            a = jnp.maximum(amax.astype(jnp.float32), 1e-6)
            wmax = jnp.maximum(jnp.max(jnp.abs(w2d), axis=1), 1e-6)
            if mode == "smoothquant":
                s = jnp.sqrt(a) / jnp.sqrt(wmax)
            else:  # awq: pick alpha minimizing activation-weighted error
                best, best_err = None, jnp.inf
                for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
                    s_try = jnp.maximum(a ** alpha / wmax ** (1 - alpha), 1e-6)
                    wq = qdq_scaled(w2d, s_try)
                    err = jnp.sum(((wq - w2d) * a[:, None]) ** 2)
                    best, best_err = jax.lax.cond(
                        err < best_err, lambda: (s_try, err),
                        lambda: (best, best_err)) if best is not None else \
                        (s_try, err)
                s = best
            s = jnp.maximum(s / jnp.maximum(jnp.max(s), 1e-6), 1e-4)
            return qdq_scaled(w2d, s)

        if wp32.ndim == 2:
            wq = leaf_2d(wp32, wb32)
        else:  # stacked layers: per-slice stats in call order
            slices = []
            for t in range(wp32.shape[0]):
                slices.append(leaf_2d(wp32[t], wb32[t]))
            wq = jnp.stack(slices)
        dq = wq - wb32
        parts_c.append(M.partial_sums(dp, dq, tuple(range(dp.ndim))))
        out.append(wq.astype(jnp.float32))

    agg = {k: sum(jnp.sum(p[k]) for p in parts_c)
           for k in ("sq_err", "n_sign_match", "dot", "dp_sq", "dq_sq",
                     "count")}
    gm = {k: float(v) for k, v in M.metrics_from_partials(agg).items()}
    return jax.tree_util.tree_unflatten(treedef, out), gm


def equalized_baseline(model, params_post, params_base, spec, *,
                       mode: str, qcfg: QuantConfig) -> dict:
    stats = collect_input_stats(model, params_post, spec)
    qparams, gm = _equalize_quantize(params_post, params_base, stats, qcfg,
                                     mode=mode)
    scores = evaluate(model, qparams, spec)
    return {"delta_l2": gm["delta_l2"], "sign_rate": gm["sign_rate"],
            "cosine": gm["cosine"], "mse": gm["mse"],
            "style": scores["style"], "general": scores["general"]}


# ---------------------------------------------------------------------------
# The tables
# ---------------------------------------------------------------------------

RANGES = [(0.5, 2.0), (0.8, 1.25), (0.9, 1.11)]


def run_tables(tables=("2", "3", "4", "5"), *, retrain: bool = False,
               out_path: str = os.path.join(STUDY_DIR, "tables.json"),
               extra_qcfg: dict | None = None) -> dict:
    model, params_base, params_post = prepare_models(retrain=retrain)
    spec = language()
    results: dict = json.load(open(out_path)) if os.path.exists(out_path) \
        else {}

    def put(table, row_name, row):
        results.setdefault(table, {})[row_name] = row
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        cols = " ".join(f"{k}={v:.4f}" for k, v in row.items()
                        if isinstance(v, float))
        print(f"[T{table}] {row_name:34s} {cols}", flush=True)

    kw = {"fmt": STUDY_FMT, "block_size": STUDY_BLOCK, **(extra_qcfg or {})}
    fmt_tag = kw["fmt"]

    if "2" in tables:
        if "base_bf16" not in results.get("2", {}):
            s = evaluate(model, params_base, spec)
            put("2", "base_bf16", {"style": s["style"],
                                   "general": s["general"]})
        if "post_bf16" not in results.get("2", {}):
            s = evaluate(model, params_post, spec)
            put("2", "post_bf16", {"style": s["style"], "general": s["general"],
                                   "delta_l2": 0.0, "sign_rate": 1.0,
                                   "cosine": 1.0})
        for gran in ("block", "channel"):
            for fmt in (fmt_tag, "fp8_e4m3"):
                name = f"absmax_{fmt}_{gran}"
                if name not in results.get("2", {}):
                    q = QuantConfig(**{**kw, "fmt": fmt,
                                       "granularity": gran})
                    put("2", name, quantize_and_eval(
                        model, params_post, params_base, q, spec,
                        absmax_only=True))
        for mode in ("smoothquant", "awq"):
            name = f"{mode}_{fmt_tag}_channel"
            if name not in results.get("2", {}):
                q = QuantConfig(**{**kw, "granularity": "channel"})
                put("2", name, equalized_baseline(
                    model, params_post, params_base, spec, mode=mode,
                    qcfg=q))

    metric_tables = {"3": "mse", "4": "sign", "5": "cosine"}
    for t, metric in metric_tables.items():
        if t not in tables:
            continue
        for gran in ("block", "channel"):
            for (lo, hi) in RANGES:
                name = f"{metric}_{gran}_[{lo},{hi}]"
                if name in results.get(t, {}):
                    continue
                q = QuantConfig(metric=metric, granularity=gran,
                                alpha_min=lo, alpha_max=hi,
                                n_coarse=5, n_fine=10, **kw)
                put(t, name, quantize_and_eval(
                    model, params_post, params_base, q, spec))
    return results
