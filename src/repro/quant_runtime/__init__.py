from repro.quant_runtime.qparams import QuantizedTensor
from repro.quant_runtime import qlinear

__all__ = ["QuantizedTensor", "qlinear"]
