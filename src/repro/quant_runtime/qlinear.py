"""Quantization-aware linear application.

Every matmul in the model zoo routes through :func:`matmul` so that a weight
leaf may transparently be either a dense array or a
:class:`~repro.quant_runtime.qparams.QuantizedTensor`.

On TPU the 2-D fp8 case uses the fused dequant-matmul Pallas kernel
(`repro.kernels.fp8_matmul`); elsewhere (CPU dry-run / interpret) it
dequantizes and lets XLA fuse the multiply into the matmul epilogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant_runtime.qparams import QuantizedTensor

# Toggled by launch configs; kernels need a real TPU (or interpret mode).
USE_KERNELS = False

# Calibration hook: when set to a list, every matmul appends
# (weight_shape, per-in-channel |x| max) -- used by the SmoothQuant/AWQ
# baselines with runtime.flags["unroll_layers"] so values are concrete.
RECORD: list | None = None


def resolve(w):
    """Return a dense array for a (possibly quantized) weight leaf."""
    if isinstance(w, QuantizedTensor):
        return w.dequantize()
    return w


def matmul(x: jnp.ndarray, w, *, precision=None) -> jnp.ndarray:
    """x @ w with w possibly quantized. x: [..., in], w: [in, out]."""
    if RECORD is not None and not isinstance(x, jax.core.Tracer):
        RECORD.append((tuple(resolve(w).shape),
                       jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)))
    if isinstance(w, QuantizedTensor):
        if USE_KERNELS and w.ndim == 2 and w.fmt.startswith("fp8"):
            from repro.kernels import fp8_matmul  # lazy: pallas import cost
            return fp8_matmul.ops.matmul_fp8(x, w)
        w = w.dequantize()
    return jnp.matmul(x, w.astype(x.dtype), precision=precision)


def take(embedding, ids):
    """Embedding lookup with optional quantized table."""
    table = resolve(embedding)
    return jnp.take(table, ids, axis=0)
