"""Quantization-aware linear application.

Every matmul in the model zoo routes through :func:`matmul` so that a weight
leaf may transparently be either a dense array or a
:class:`~repro.quant_runtime.qparams.QuantizedTensor`.

On TPU the 2-D fp8 case uses the fused dequant-matmul Pallas kernel
(`repro.kernels.fp8_matmul`); elsewhere (CPU dry-run / interpret) it
dequantizes and lets XLA fuse the multiply into the matmul epilogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant_runtime.qparams import QuantizedTensor

# Toggled by launch configs; kernels need a real TPU (or interpret mode).
USE_KERNELS = False

# Calibration hook: when set to a list, every matmul appends
# (weight_shape, weight_fingerprint, per-in-channel |x| max) -- used by the
# SmoothQuant/AWQ methods with runtime.flags["unroll_layers"] so values are
# concrete.
RECORD: list | None = None


def weight_fingerprint(w) -> tuple:
    """Stable identity of a dense 2-D weight for calibration matching.

    Shape alone collides (wq/wo, wk/wv, gate/up all share shapes), so stats
    are keyed by sampled values instead: bf16->f32 casts are exact, so the
    fingerprint computed here during the forward equals the one computed
    from the parameter-tree leaf at quantization time.

    Contract: fingerprints only match when calibration and quantization run
    on the same backend/JAX build (the mean-abs reduction order must be
    identical).  A serialized ``calib=`` list from a different device class
    may miss every lookup — the equalize methods warn on the first miss.
    """
    w32 = jnp.asarray(w, jnp.float32)
    return (float(w32[0, 0]), float(w32[-1, -1]),
            float(jnp.mean(jnp.abs(w32))))


def resolve(w):
    """Return a dense array for a (possibly quantized) weight leaf."""
    if isinstance(w, QuantizedTensor):
        return w.dequantize()
    return w


def matmul(x: jnp.ndarray, w, *, precision=None) -> jnp.ndarray:
    """x @ w with w possibly quantized. x: [..., in], w: [in, out]."""
    if RECORD is not None and not isinstance(x, jax.core.Tracer):
        w_res = resolve(w)
        RECORD.append((tuple(w_res.shape), weight_fingerprint(w_res),
                       jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)))
    if isinstance(w, QuantizedTensor):
        # fused kernel dequantizes q*scale only; equalized tensors need the
        # extra /eq_scale epilogue, so they take the XLA dequantize path
        if USE_KERNELS and w.ndim == 2 and w.fmt.startswith("fp8") \
                and w.eq_scale is None:
            from repro.kernels import fp8_matmul  # lazy: pallas import cost
            return fp8_matmul.ops.matmul_fp8(x, w)
        w = w.dequantize()
    return jnp.matmul(x, w.astype(x.dtype), precision=precision)


def matmul_t(x: jnp.ndarray, w) -> jnp.ndarray:
    """``x @ w.T`` with ``w`` possibly quantized — the tied-embedding LM
    head (``x [..., D] @ table[V, D].T``), which is the hot op of a
    speculative *draft* forward over storage-mode weights: every draft
    decode step projects to the full vocabulary.

    For tensor/channel-granularity :class:`QuantizedTensor` tables the
    scale factors move to the cheap side of the transpose instead of
    materializing the dequantized ``[V, D]`` table per step:

      ``x @ (q * s).T  ==  (x * s[0]) @ q.T``      (channel: s is [1, D])
      ``x @ (q * s).T  ==  s * (x @ q.T)``         (tensor: s is scalar)

    and a row-wise ``eq_scale`` divides the output columns.  Value-wise
    this matches ``x @ w.dequantize().T`` up to fp reassociation; block
    granularity (scales tile both axes) falls back to the dequantize path.
    """
    if not isinstance(w, QuantizedTensor):
        return jnp.matmul(x, w.T.astype(x.dtype))
    if w.ndim == 2 and w.granularity in ("tensor", "channel"):
        q = w.data.astype(jnp.float32)
        x32 = x.astype(jnp.float32)
        if w.granularity == "channel":      # scale [1, D] over columns of w
            out = jnp.matmul(x32 * w.scale.astype(jnp.float32)[0], q.T)
        else:                               # scalar scale
            out = jnp.matmul(x32, q.T) * jnp.float32(w.scale)
        if w.eq_scale is not None:          # per-row divisor of w
            out = out / w.eq_scale.astype(jnp.float32)
        return out.astype(x.dtype)
    return jnp.matmul(x, w.dequantize().T.astype(x.dtype))


def take(embedding, ids):
    """Embedding lookup with optional quantized table.

    For a :class:`QuantizedTensor` table only the gathered rows are
    dequantized — dequantizing the whole ``[V, D]`` table per lookup made
    every decode step O(vocab) in storage mode.
    """
    if isinstance(embedding, QuantizedTensor) and embedding.ndim == 2:
        return _take_quantized(embedding, ids)
    return jnp.take(resolve(embedding), ids, axis=0)


def _take_quantized(w: QuantizedTensor, ids):
    """Row-gathered dequantization, matching ``w.dequantize()[ids]`` exactly
    (same fp32 q*scale math, same eq_scale epilogue)."""
    from repro.core.formats import get_format
    get_format(w.fmt)  # validate early; the math below is format-agnostic
    flat = jnp.asarray(ids, jnp.int32).reshape(-1)
    q = jnp.take(w.data, flat, axis=0).astype(jnp.float32)     # [N, O]
    if w.granularity == "block":
        # scale [I/bs, 1, O/bs, 1]: row r uses scale row r // bs; expand the
        # per-column-block scale to the (unpadded) O columns
        bs = w.block_size
        s = jnp.take(w.scale, flat // bs, axis=0)[:, 0, :, 0]  # [N, O/bs]
        s = jnp.repeat(s, bs, axis=1)[:, : q.shape[-1]]
        rows = q * s
    else:  # tensor: scalar; channel: [1, O] — both broadcast over rows
        rows = q * w.scale
    if w.eq_scale is not None:
        rows = rows / jnp.take(w.eq_scale, flat, axis=0)[:, None]
    out = rows.astype(jnp.dtype(w.out_dtype))
    return out.reshape(*jnp.shape(ids), out.shape[-1])
