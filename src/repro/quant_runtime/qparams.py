"""QuantizedTensor: a pytree node holding low-precision weight storage.

Model code calls ``repro.quant_runtime.qlinear.matmul(x, w)`` for every
linear; when ``w`` is a ``QuantizedTensor`` the weight is dequantized on the
fly (or fed to the fused Pallas dequant-matmul kernel on TPU).  Because the
node is a registered pytree, quantized parameter trees flow through
``jax.jit``, ``jax.eval_shape``, shardings and checkpointing unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.formats import get_format
from repro.core.granularity import dequantize_stored


@dataclass
class QuantizedTensor:
    data: jnp.ndarray            # storage repr (fp8/int8), same layout as W
    scale: jnp.ndarray           # broadcastable scales (see granularity.py)
    fmt: str = "fp8_e4m3"        # static
    granularity: str = "block"   # static
    block_size: int = 128        # static
    out_dtype: str = "bfloat16"  # static: dequantization target dtype
    eq_scale: jnp.ndarray | None = None  # per-in-channel equalization s:
                                         # data stores Q(W*s), dequant /= s

    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    def dequantize(self) -> jnp.ndarray:
        fmt = get_format(self.fmt)
        dt = jnp.dtype(self.out_dtype)
        if self.eq_scale is None:
            fn = lambda d, s: dequantize_stored(d, s, self.granularity, fmt,
                                                self.block_size, dt)
            args = (self.data, self.scale)
        else:
            def fn(d, s, e):
                w = dequantize_stored(d, s, self.granularity, fmt,
                                      self.block_size, jnp.float32)
                return (w / e[:, None]).astype(dt)
            args = (self.data, self.scale, self.eq_scale)
        # stacked layers: vmap the 2-D dequant over leading axes
        for _ in range(self.data.ndim - 2):
            fn = jax.vmap(fn)
        return fn(*args)

    def nbytes(self) -> int:
        fmt = get_format(self.fmt)
        n = self.data.size * fmt.bits // 8 + self.scale.size * 4
        if self.eq_scale is not None:
            n += self.eq_scale.size * 4
        return n


jax.tree_util.register_dataclass(
    QuantizedTensor,
    data_fields=["data", "scale", "eq_scale"],
    meta_fields=["fmt", "granularity", "block_size", "out_dtype"],
)
