"""Public quantization API: one entry point, a pluggable method registry.

    from repro.quantize import quantize
    qtree, report = quantize(params_post, params_base,
                             QuantConfig(method="daq", metric="sign"))

See README.md §"Public quantization API" and :mod:`repro.quantize.api`.
"""
from repro.quantize.api import LeafContext, QuantReport, Quantizer, quantize
from repro.quantize.daq import AbsMaxQuantizer, DAQQuantizer  # noqa: F401
from repro.quantize.equalize import collect_input_stats
from repro.quantize.registry import available_methods, get_method, register

__all__ = [
    "LeafContext", "QuantReport", "Quantizer", "quantize",
    "collect_input_stats", "available_methods", "get_method", "register",
]
