"""The public quantization surface: ``quantize(params_post, params_base, qcfg)``.

One entry point owns the parameter-tree walk, the skip policy, the exact
global delta-metric aggregation (partial sums combined across leaves), and
the storage-vs-dequant emission; the per-leaf math is delegated to a
:class:`Quantizer` resolved from the method registry:

  ``"absmax"``        AbsMax baseline (search collapsed to alpha = 1)
  ``"daq"``           paper Alg. 1 scale search, metric from ``qcfg.metric``
  ``"daq-per-block"`` beyond-paper independent alpha per block/channel
  ``"smoothquant"``   activation-aware equalization, fixed alpha = 0.5
  ``"awq"``           activation-aware equalization, alpha grid by output MSE

Calibration-based methods receive activation statistics through the
``calibrate`` hook (pass ``model=``/``spec=`` or a precomputed ``calib=``
list); data-free methods ignore those arguments.  The legacy
``repro.core.daq.quantize_tree`` / ``absmax_tree`` are deprecated shims over
this function.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core import metrics as M
from repro.core.policy import path_str, should_quantize
from repro.core.search import SearchResult
from repro.quant_runtime.qparams import QuantizedTensor
from repro.quantize.registry import get_method

_PARTIAL_KEYS = ("sq_err", "n_sign_match", "dot", "dp_sq", "dq_sq", "count")


# ---------------------------------------------------------------------------
# Per-leaf context + method protocol
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafContext:
    """Everything a :class:`Quantizer` sees for one eligible leaf."""
    name: str                    # joined key path, e.g. "stack/L0/attn/wq"
    w_post: jnp.ndarray          # post-trained weight (>= 2-D)
    w_base: jnp.ndarray          # base weight, same shape
    qcfg: QuantConfig            # method-resolved config


class Quantizer:
    """Base class / protocol for registered quantization methods.

    Subclasses implement ``prepare`` (per-leaf) and may override:

      * ``resolve_config(qcfg)`` — normalize the config before the walk
        (e.g. AbsMax collapses every search knob);
      * ``calibrate(model, params, spec, n_batches=...)`` — produce
        activation statistics for calibration-based methods;
      * ``set_calibration(calib)`` — install (possibly precomputed) stats.
    """

    name: str = ""
    requires_calibration: bool = False

    def resolve_config(self, qcfg: QuantConfig) -> QuantConfig:
        return qcfg

    def calibrate(self, model, params, spec, *, n_batches: int = 2) -> Any:
        return None

    def set_calibration(self, calib: Any) -> None:
        pass

    def prepare(self, ctx: LeafContext) -> SearchResult:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclass
class QuantReport:
    per_leaf: dict[str, dict] = field(default_factory=dict)
    global_chosen: dict[str, float] = field(default_factory=dict)
    global_default: dict[str, float] = field(default_factory=dict)
    n_quantized: int = 0
    n_skipped: int = 0
    quantized_bytes: int = 0
    original_bytes: int = 0
    method: str = ""

    def summary(self) -> str:
        g, d = self.global_chosen, self.global_default
        lines = [
            f"quantized {self.n_quantized} tensors ({self.n_skipped} skipped), "
            f"{self.original_bytes / 1e6:.1f} MB -> {self.quantized_bytes / 1e6:.1f} MB",
            f"  delta_l2   : {d.get('delta_l2', 0):.4g} -> {g.get('delta_l2', 0):.4g}",
            f"  sign_rate  : {d.get('sign_rate', 0):.4f} -> {g.get('sign_rate', 0):.4f}",
            f"  cosine     : {d.get('cosine', 0):.4f} -> {g.get('cosine', 0):.4f}",
            f"  mse        : {d.get('mse', 0):.4g} -> {g.get('mse', 0):.4g}",
        ]
        if self.method:
            lines.insert(0, f"method: {self.method}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def _scalar_sum(x) -> float:
    return float(jnp.sum(x))


def _mean_metric(d: dict, m: str) -> float:
    """Per-leaf metric: mean over stacked layers when the leaf was vmapped."""
    return float(jnp.mean(d[m]))


def quantize(params_post: Any, params_base: Any = None,
             qcfg: QuantConfig | None = None, *, mode: str = "dequant",
             out_dtype: str = "float32", method: str | None = None,
             model=None, spec=None, calib: Any = None,
             calib_batches: int = 2) -> tuple[Any, QuantReport]:
    """Quantize every eligible leaf of ``params_post``.

    Args:
      params_post: pytree of post-trained weights.
      params_base: matching pytree of base weights for the delta-aware
        objectives; ``None`` uses ``params_post`` itself (zero delta —
        reconstruction-only regime, delta metrics degenerate).
      qcfg: :class:`QuantConfig`; ``qcfg.method`` selects the algorithm.
      mode: ``"dequant"`` returns float weights (evaluation / benchmarks);
        ``"storage"`` returns :class:`QuantizedTensor` nodes (serving).
      out_dtype: dtype of emitted weights (dequant) / dequantization target
        (storage).
      method: registry-name override of ``qcfg.method``.
      model, spec: forwarded to the method's ``calibrate`` hook when the
        method requires calibration and no ``calib`` was given.
      calib: precomputed calibration statistics (skips ``calibrate``).
      calib_batches: batches for the ``calibrate`` hook.

    Returns:
      ``(quantized_tree, QuantReport)`` — the report carries per-leaf alphas
      and exact global delta metrics at both the chosen and default scales.
    """
    if qcfg is None:
        qcfg = QuantConfig()
    if mode not in ("dequant", "storage"):
        raise ValueError(f"mode must be 'dequant' or 'storage', got {mode!r}")
    name = method or qcfg.method
    quantizer: Quantizer = get_method(name)()
    qcfg = quantizer.resolve_config(qcfg)
    if params_base is None:
        params_base = params_post

    if quantizer.requires_calibration:
        if calib is None and (model is None) != (spec is None):
            raise ValueError(
                f"method {name!r} requires calibration: pass BOTH model= "
                "and spec= (or a precomputed calib= list)")
        if calib is None and model is not None:
            calib = quantizer.calibrate(model, params_post, spec,
                                        n_batches=calib_batches)
        quantizer.set_calibration(calib)

    report = QuantReport(method=name)
    post_leaves, treedef = jax.tree_util.tree_flatten_with_path(params_post)
    base_leaves = jax.tree_util.tree_leaves(params_base)
    if len(post_leaves) != len(base_leaves):
        raise ValueError("post/base parameter trees differ in structure")

    agg_c = {k: 0.0 for k in _PARTIAL_KEYS}
    agg_d = {k: 0.0 for k in _PARTIAL_KEYS}

    out_leaves = []
    for (path, w_post), w_base in zip(post_leaves, base_leaves):
        leaf_name = path_str(path)
        if not should_quantize(leaf_name, w_post, qcfg.skip_patterns):
            report.n_skipped += 1
            out_leaves.append(w_post)
            continue
        res = quantizer.prepare(LeafContext(leaf_name, w_post, w_base, qcfg))
        report.n_quantized += 1
        report.original_bytes += w_post.size * w_post.dtype.itemsize
        for k in _PARTIAL_KEYS:
            agg_c[k] += _scalar_sum(res.chosen[k])
            agg_d[k] += _scalar_sum(res.default[k])
        report.per_leaf[leaf_name] = {
            "alpha": jax.device_get(res.alpha),
            "chosen": {m: _mean_metric(res.chosen, m) for m in
                       ("mse", "sign_rate", "cosine", "delta_l2")},
            "default": {m: _mean_metric(res.default, m) for m in
                        ("mse", "sign_rate", "cosine", "delta_l2")},
            "shape": tuple(w_post.shape),
        }
        if mode == "storage":
            qt = QuantizedTensor(data=res.w_q, scale=res.scale, fmt=qcfg.fmt,
                                 granularity=qcfg.granularity,
                                 block_size=qcfg.block_size,
                                 out_dtype=out_dtype, eq_scale=res.eq_scale)
            report.quantized_bytes += qt.nbytes()
            out_leaves.append(qt)
        else:
            from repro.core.formats import get_format
            nbytes = (w_post.size * get_format(qcfg.fmt).bits // 8
                      + res.scale.size * 4)
            if res.eq_scale is not None:
                nbytes += res.eq_scale.size * 4
            report.quantized_bytes += nbytes
            out_leaves.append(res.w_dq.astype(jnp.dtype(out_dtype)))

    agg_cj = {k: jnp.asarray(v) for k, v in agg_c.items()}
    agg_dj = {k: jnp.asarray(v) for k, v in agg_d.items()}
    report.global_chosen = {k: float(v) for k, v in
                            M.metrics_from_partials(agg_cj).items()}
    report.global_default = {k: float(v) for k, v in
                             M.metrics_from_partials(agg_dj).items()}
    return jax.tree_util.tree_unflatten(treedef, out_leaves), report
