"""Registered data-free methods: DAQ (paper Alg. 1) and the AbsMax baseline.

The per-leaf search lives in :mod:`repro.core.search`; stacked-layer leaves
``[L, I, O]`` are handled by vmapping the per-matrix search over the leading
axes — one alpha per layer, exactly Alg. 1's per-layer loop.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import QuantConfig
from repro.core.search import SearchResult, search_scale
from repro.quantize.api import LeafContext, Quantizer
from repro.quantize.registry import register


@register("daq")
class DAQQuantizer(Quantizer):
    """Delta-aware coarse-to-fine scale search; objective = ``qcfg.metric``.

    Honors ``qcfg.per_block_alpha`` / ``qcfg.use_fused_kernel`` exactly like
    the per-leaf search always has (``search_scale`` dispatches internally).
    """

    def prepare(self, ctx: LeafContext) -> SearchResult:
        qcfg = ctx.qcfg
        fn = lambda p, b: search_scale(p, b, qcfg)
        for _ in range(ctx.w_post.ndim - 2):
            fn = jax.vmap(fn)
        return fn(ctx.w_post, ctx.w_base)


@register("daq-per-block")
class DAQPerBlockQuantizer(DAQQuantizer):
    """Beyond-paper variant: independent alpha per block / channel."""

    def resolve_config(self, qcfg: QuantConfig) -> QuantConfig:
        return dataclasses.replace(qcfg, per_block_alpha=True,
                                   use_fused_kernel=False)


@register("absmax")
class AbsMaxQuantizer(DAQQuantizer):
    """AbsMax baseline = Alg. 1 with an empty search (alpha fixed at 1).

    Collapsing the search must clear *every* search knob, not just the grid:
    ``per_block_alpha`` and ``use_fused_kernel`` are reset so a caller with a
    fused-sweep or per-block config still gets a plain AbsMax baseline.
    """

    def resolve_config(self, qcfg: QuantConfig) -> QuantConfig:
        return dataclasses.replace(qcfg, n_coarse=1, n_fine=1,
                                   alpha_min=1.0, alpha_max=1.0,
                                   per_block_alpha=False,
                                   use_fused_kernel=False)
