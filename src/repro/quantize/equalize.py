"""Registered calibration-based methods: SmoothQuant and AWQ (weight-only).

Both quantize ``Q(W diag(s)) / diag(s)`` — numerically the same space as W,
so the delta metrics stay well-defined (a bonus over the papers' absorbed
formulation).  The per-input-channel equalization vector ``s`` comes from
activation statistics collected by :func:`collect_input_stats`, which flows
through the :meth:`Quantizer.calibrate` hook; without calibration the
methods fall back to unit activation scales (with a warning).

In storage mode the equalization vector rides along on the emitted
:class:`QuantizedTensor` (``eq_scale``), so equalized trees serve through
the same ``qlinear`` path as DAQ trees.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig
from repro.core.formats import Format, get_format
from repro.core.granularity import absmax_scale, apply_qdq, quantize_store
from repro.core.search import SearchResult, metrics_and_partials
from repro.quantize.api import LeafContext, Quantizer
from repro.quantize.registry import register


def collect_input_stats(model, params, spec, n_batches: int = 2) -> list:
    """Eager unrolled forward; returns [(w_shape, w_fingerprint, absmax[in])].

    Records are keyed by the weight's value fingerprint
    (:func:`repro.quant_runtime.qlinear.weight_fingerprint`), not by shape —
    same-shaped weights (wq/wo, gate/up, ...) would otherwise collide.
    Raw per-call records are returned; repeated calls of one weight (across
    batches or call sites) are max-merged by ``set_calibration``.
    """
    from repro import runtime
    from repro.data.synthetic import _full_logits, sample_batch
    from repro.quant_runtime import qlinear

    prev_unroll = runtime.flags["unroll_layers"]
    runtime.flags["unroll_layers"] = True
    qlinear.RECORD = []
    try:
        for i in range(n_batches):
            toks = sample_batch(jax.random.PRNGKey(500 + i), spec, 4, 64)
            _full_logits(model, params,
                         {"tokens": toks[:, :-1], "labels": toks[:, 1:]})
        return qlinear.RECORD
    finally:
        qlinear.RECORD = None
        runtime.flags["unroll_layers"] = prev_unroll


class _EqualizeQuantizer(Quantizer):
    """Shared machinery: stats matching, Q(W·s)/s, delta metrics."""

    requires_calibration = True

    def __init__(self):
        self._stats: dict[tuple, jnp.ndarray] = {}
        self._warned_miss = False

    def calibrate(self, model, params, spec, *, n_batches: int = 2) -> list:
        return collect_input_stats(model, params, spec, n_batches)

    def set_calibration(self, calib) -> None:
        # stats match leaves by (shape, value-fingerprint) — exact, no
        # call-order bookkeeping; fingerprint collisions max-merge
        self._stats = {}
        if not calib:  # None or empty: nothing was recorded at all
            warnings.warn(
                f"{self.name}: no calibration stats (pass model=/spec= or "
                "calib=); equalization falls back to unit activation scales",
                stacklevel=3)
            return
        for shape, fp, amax in calib:
            key = (tuple(shape), fp)
            prev = self._stats.get(key)
            self._stats[key] = amax if prev is None \
                else jnp.maximum(prev, amax)

    def _equalization(self, w2d: jnp.ndarray, a: jnp.ndarray,
                      wmax: jnp.ndarray, qcfg: QuantConfig,
                      fmt: Format) -> jnp.ndarray:
        raise NotImplementedError

    def _prepare_2d(self, wp, wb, qcfg: QuantConfig,
                    name: str = "?") -> SearchResult:
        from repro.quant_runtime.qlinear import weight_fingerprint
        fmt = get_format(qcfg.fmt)
        wp32 = wp.astype(jnp.float32)
        wb32 = wb.astype(jnp.float32)
        dp = wp32 - wb32

        amax = self._stats.get((tuple(wp.shape), weight_fingerprint(wp)))
        if amax is None:
            # a miss with stats present means the forward saw different
            # weight values than this leaf — surface it once rather than
            # silently degrading to unit scales everywhere.  Embedding
            # tables are exempt: they go through qlinear.take, never
            # qlinear.matmul, so no record can exist for them by design.
            if self._stats and not self._warned_miss \
                    and "embed" not in name.lower():
                self._warned_miss = True
                warnings.warn(
                    f"{self.name}: no calibration record matches leaf "
                    f"{name!r} {tuple(wp.shape)}; it (and any further "
                    "unmatched leaves) equalize with unit activation scales",
                    stacklevel=2)
            amax = jnp.ones((wp32.shape[0],), jnp.float32)
        a = jnp.maximum(amax.astype(jnp.float32), 1e-6)
        wmax = jnp.maximum(jnp.max(jnp.abs(wp32), axis=1), 1e-6)
        s = self._equalization(wp32, a, wmax, qcfg, fmt)
        s = jnp.maximum(s / jnp.maximum(jnp.max(s), 1e-6), 1e-4)

        ws = wp32 * s[:, None]
        scale = absmax_scale(ws, qcfg.granularity, fmt, qcfg.block_size)
        w_q = quantize_store(ws, scale, qcfg.granularity, fmt, qcfg.block_size)
        w_dq = apply_qdq(ws, scale, qcfg.granularity, fmt,
                         qcfg.block_size) / s[:, None]
        # default baseline: plain AbsMax at the same granularity, no
        # equalization — mirrors SearchResult.default for the DAQ methods
        s0 = absmax_scale(wp32, qcfg.granularity, fmt, qcfg.block_size)
        w_dq0 = apply_qdq(wp32, s0, qcfg.granularity, fmt, qcfg.block_size)
        return SearchResult(alpha=s, scale=scale, w_q=w_q, w_dq=w_dq,
                            chosen=metrics_and_partials(dp, w_dq - wb32),
                            default=metrics_and_partials(dp, w_dq0 - wb32),
                            eq_scale=s)

    def prepare(self, ctx: LeafContext) -> SearchResult:
        return self._prepare_nd(ctx.w_post, ctx.w_base, ctx.qcfg, ctx.name)

    def _prepare_nd(self, wp, wb, qcfg: QuantConfig,
                    name: str) -> SearchResult:
        if wp.ndim == 2:
            return self._prepare_2d(wp, wb, qcfg, name)
        # stacked layers: each slice looks up its own stats by fingerprint
        # (python loop — the dict lookup is host-side, so no vmap)
        parts = [self._prepare_nd(wp[t], wb[t], qcfg, f"{name}[{t}]")
                 for t in range(wp.shape[0])]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *parts)


def _qdq_scaled(w2d, s_vec, qcfg: QuantConfig, fmt: Format):
    ws = w2d * s_vec[:, None]
    sc = absmax_scale(ws, qcfg.granularity, fmt, qcfg.block_size)
    return apply_qdq(ws, sc, qcfg.granularity, fmt,
                     qcfg.block_size) / s_vec[:, None]


@register("smoothquant")
class SmoothQuantQuantizer(_EqualizeQuantizer):
    """Fixed migration strength alpha = 0.5: s = sqrt(a_max) / sqrt(w_max)."""

    def _equalization(self, w2d, a, wmax, qcfg, fmt):
        return jnp.sqrt(a) / jnp.sqrt(wmax)


@register("awq")
class AWQQuantizer(_EqualizeQuantizer):
    """Alpha grid per leaf, picked by activation-weighted output MSE."""

    GRID = (0.0, 0.25, 0.5, 0.75, 1.0)

    def _equalization(self, w2d, a, wmax, qcfg, fmt):
        s_tries = jnp.stack([jnp.maximum(a ** al / wmax ** (1 - al), 1e-6)
                             for al in self.GRID])
        errs = jnp.stack([
            jnp.sum(((_qdq_scaled(w2d, s, qcfg, fmt) - w2d) * a[:, None]) ** 2)
            for s in s_tries])
        return s_tries[jnp.argmin(errs)]
