"""String-keyed registry of quantization methods.

Every quantization algorithm in the repo — DAQ's delta-aware scale search,
the AbsMax baseline, and the calibration-based SmoothQuant / AWQ
equalization baselines — registers here under a short name.  The single
entry point :func:`repro.quantize.quantize` resolves ``QuantConfig.method``
(or an explicit ``method=`` override) through this table, so adding a new
format/algorithm is one ``@register("name")`` class, not another fork of the
tree-walk loop.
"""
from __future__ import annotations

_REGISTRY: dict[str, type] = {}

# The built-in method modules (repro.quantize.daq / .equalize) register
# themselves when the package __init__ imports them; they cannot be
# imported here because they subclass Quantizer from repro.quantize.api,
# which imports this registry — that would be a cycle.  Importing any part
# of the package runs __init__ first, so lookups always see the builtins.


def register(name: str):
    """Class decorator: register a :class:`Quantizer` under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_method(name: str) -> type:
    """Resolve a method name to its :class:`Quantizer` class."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown quantization method {name!r}; "
                       f"available: {sorted(_REGISTRY)}") from None


def available_methods() -> list[str]:
    return sorted(_REGISTRY)
