"""Process-wide runtime/perf flags (no arch semantics — see QuantConfig /
ModelConfig for those).

These are read at *trace time*; callers that flip them must rebuild/re-lower
(build_model returns fresh closures, so a fresh Model + jit is enough).
They exist so the perf-iteration loop (EXPERIMENTS.md §Perf) can toggle
structural choices without threading knobs through every layer signature.
"""
from __future__ import annotations

flags: dict = {
    # Megatron-style sequence parallelism: residual stream is sharded over
    # the `model` axis between layers (all-gather at QKV/MLP-in,
    # reduce-scatter after out-proj — GSPMD derives the pair from the carry
    # constraint).  Cuts per-layer saved activations by model_size.
    "seq_shard": True,
    # constrain the residual batch dim over the dp axes between layers
    "batch_constraint": True,
    # MoE dispatch group target size (tokens per routing group)
    "moe_group": 1024,
    # attention q/kv chunk sizes for the online-softmax scan
    "q_chunk": 1024,
    "kv_chunk": 1024,
    # debug/calibration: python-loop over layers instead of lax.scan, so an
    # eager forward sees concrete per-layer values (SmoothQuant/AWQ stats)
    "unroll_layers": False,
    # KV-cache storage dtype: "bfloat16" | "float8_e4m3fn".  fp8 halves the
    # cache-read traffic that dominates long-context decode (k/v values are
    # O(1-10) so the unscaled E4M3 range is safe; ~6% relative noise on
    # attention scores — beyond-paper optimization, see EXPERIMENTS.md §Perf)
    "kv_cache_dtype": "bfloat16",
    # MoE expert-weight layout (launch/sharding.py):
    #   "ep_model"          E over `model`, D over `data` (FSDP) — weights
    #                       all-gathered over data at every use
    #   "ep_data_tp_model"  E over `data`, F over `model` — weights fully
    #                       local; REFUTED for the GShard einsum-dispatch
    #                       formulation (kimi train collective 160s -> 526s:
    #                       routing tensors blow up when E shards the batch
    #                       axis).  Kept for the §Perf log; a sort-based
    #                       all-to-all dispatch would be needed to win here.
    "moe_sharding": "ep_model",
}


def _mesh_axes():
    import jax
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None, None, 0
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names) or None
        msz = mesh.shape["model"] if "model" in names else 0
        return names, dp, msz
    except Exception:
        return None, None, 0


def attn_shard_specs(kv_heads: int, q_groups: int):
    """(q_spec, kv_spec) for grouped-GQA tensors q [B,S,Kv,G,hd],
    k/v [B,S,Kv,hd] — tiered: shard KV heads over `model` when divisible,
    else shard the q groups (kv replicated over model), else no constraint.
    Returns (None, None) when no mesh/model axis is available."""
    from jax.sharding import PartitionSpec as P

    names, dp, msz = _mesh_axes()
    if not msz or msz == 1:
        return None, None
    if kv_heads % msz == 0:
        return (P(dp, None, "model", None, None), P(dp, None, "model", None))
    if q_groups % msz == 0:
        return (P(dp, None, None, "model", None), P(dp, None, None, None))
    return None, None


def kv_repeat_factor(kv_heads: int, q_groups: int,
                     for_cache: bool = False) -> int:
    """GQA KV-repeat sharding: when Kv < model_size but Kv*r divides it,
    repeating each KV head r times makes the head axis model-shardable —
    per-device KV memory becomes (r/msz) of the original instead of a full
    replica (e.g. Kv=8, msz=16: r=2 -> 1/8 per device vs 1x replicated).

    ``for_cache``: train/prefill attention prefers the q-group sharding
    tier when G % msz == 0 (repeat there multiplies k/v activation compute
    — measured 2x collective regression on glm4 train), but the DECODE
    CACHE always wants the repeat: a replicated cache costs msz-times the
    memory and read traffic (glm4 decode: peak 10.1 -> 5.1 GiB,
    collectives 461 -> 33 ms).  Returns 1 when not applicable."""
    if flags.get("force_kv_repeat", 0):
        return int(flags["force_kv_repeat"])
    _, _, msz = _mesh_axes()
    if not msz or msz <= 1 or kv_heads % msz == 0:
        return 1
    if not for_cache and q_groups % msz == 0:
        return 1  # q-group sharding tier already covers this case
    if msz % kv_heads == 0:
        r = msz // kv_heads
        if q_groups % r == 0:
            return r
    return 1


def constrain(x, spec):
    import jax
    if spec is None or x is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def residual_constraint(x):
    """Apply the configured residual-stream sharding constraint (no-op when
    there is no ambient mesh, e.g. plain CPU tests)."""
    import jax
    from jax.sharding import PartitionSpec as P

    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names) or None
        seq = "model" if (flags["seq_shard"] and "model" in names) else None
        if not flags["batch_constraint"]:
            dp = None
        if dp is None and seq is None:
            return x
        spec = P(dp, seq, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
