"""Static analysis for the serving engine: compile contracts over every
jitted entry point (donation aliasing, host-sync bans, recompile
fingerprints, dtype hygiene, collective manifests) plus an AST lint for
the host/device discipline jit cannot enforce.  Run with
``python -m repro.staticcheck``; ratcheted by ``staticcheck_baseline.json``
and ``staticcheck_manifest.json`` at the repo root."""
from repro.staticcheck.report import (Report, Violation, diff_baseline,
                                      load_baseline, write_baseline)

__all__ = ["Report", "Violation", "diff_baseline", "load_baseline",
           "write_baseline"]
