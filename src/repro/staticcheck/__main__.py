"""``python -m repro.staticcheck`` — run both passes and ratchet.

Exit status 0 iff every violation is either fixed or explicitly waived in
the checked-in baseline.  ``--update`` rewrites the baseline (waiving the
current violations) and the fingerprint manifest; review the diff like
code — the ratchet only goes down.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def _force_two_devices() -> None:
    """Mesh contracts need >= 2 devices; the CPU platform fakes them, but
    only if the flag lands before jax initializes."""
    if "jax" in sys.modules:  # pragma: no cover - CLI imports us first
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=2").strip()


def repo_root(start: Path | None = None) -> Path:
    """Nearest ancestor holding the ``src/repro`` tree."""
    p = (start or Path(__file__).resolve()).parent
    while p != p.parent:
        if (p / "src" / "repro").is_dir():
            return p
        p = p.parent
    return Path.cwd()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="compile contracts + AST lint for the serving engine")
    ap.add_argument("--matrix", choices=("quick", "full", "none"),
                    default="quick",
                    help="config matrix for the compile contracts "
                         "(none = lint only)")
    ap.add_argument("--lint-only", action="store_true",
                    help="alias for --matrix none")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline and fingerprint manifest "
                         "to match the current tree")
    ap.add_argument("--report", type=Path, default=None,
                    help="write the full JSON report here")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline path (default: "
                         "<repo>/staticcheck_baseline.json)")
    ap.add_argument("--manifest", type=Path, default=None,
                    help="fingerprint manifest path (default: "
                         "<repo>/staticcheck_manifest.json)")
    args = ap.parse_args(argv)

    _force_two_devices()

    from repro.staticcheck.contracts import run_contracts
    from repro.staticcheck.lint import lint_tree
    from repro.staticcheck.report import (Report, diff_baseline,
                                          load_baseline, write_baseline)

    root = repo_root()
    baseline_path = args.baseline or root / "staticcheck_baseline.json"
    manifest_path = args.manifest or root / "staticcheck_manifest.json"
    matrix = "none" if args.lint_only else args.matrix

    report = Report()

    lint_vs, n_files = lint_tree(root / "src" / "repro")
    report.extend(lint_vs)
    report.checked["lint_files"] = n_files

    manifest: dict = {}
    new_manifest: dict = {}
    if matrix != "none":
        try:
            with open(manifest_path) as f:
                manifest = json.load(f).get("cases", {})
        except FileNotFoundError:
            manifest = {}
        contract_vs, new_manifest, counters, skipped = run_contracts(
            matrix, manifest, args.update)
        report.extend(contract_vs)
        report.checked.update(counters)
        report.skipped = skipped

    baseline = load_baseline(baseline_path)
    new, waived, stale = diff_baseline(report.violations, baseline)

    if args.report:
        out = report.to_json()
        out["new"] = [v.row() for v in new]
        out["stale_waivers"] = stale
        with open(args.report, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")

    if args.update:
        write_baseline(baseline_path, report.violations)
        if matrix != "none":
            with open(manifest_path, "w") as f:
                json.dump({"version": 1, "cases": new_manifest}, f,
                          indent=2, sort_keys=True)
                f.write("\n")
        print(f"baseline rewritten: {len(report.violations)} waiver(s) -> "
              f"{baseline_path.name}"
              + (f"; manifest: {manifest_path.name}"
                 if matrix != "none" else ""))
        return 0

    checked = ", ".join(f"{k}={v}" for k, v in sorted(
        report.checked.items()))
    print(f"staticcheck: {checked}")
    for s in report.skipped:
        print(f"  skipped {s}")
    for v in waived:
        print(f"  waived  {v.key}")
    for k in stale:
        print(f"  stale waiver (fixed? drop via --update): {k}")
    for v in new:
        loc = f"{v.where}:{v.line}" if v.line else v.where
        print(f"  FAIL [{v.rule}] {loc} ({v.symbol}): {v.msg}")
    wasted = sum(v.bytes_wasted for v in new)
    if wasted:
        print(f"  donation bytes wasted: {wasted}")
    if new:
        print(f"{len(new)} new violation(s) not in {baseline_path.name}")
        return 1
    print("clean" + (f" ({len(waived)} waived)" if waived else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
