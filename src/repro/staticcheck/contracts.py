"""Pass 1: compile contracts for every jitted engine dispatch.

For each configuration in the matrix (model family x cache mode x mesh)
this pass builds a real :class:`~repro.engine.Engine`, takes its
entry-point registry (``Engine.entry_points()``), lowers **and compiles**
each entry on canonical example inputs, and checks declarative contracts
on the jaxpr-free artifacts — the compiled HLO text and the abstract
signatures:

* **donation-not-landed** — every donated cache/pool operand must appear
  in the compiled module's ``input_output_alias`` table.  A donation XLA
  could not use means the buffer is silently copied: 2x cache memory at
  every dispatch, invisible to every runtime parity test.  Reports the
  bytes wasted.
* **host-boundary** — no infeed/outfeed/send/recv and no python-callback
  custom-calls anywhere in a traced entry.  One of these inside the
  K-step scan reintroduces the per-token host sync the dispatch exists
  to amortize (~100x on the serve bench).
* **recompile-fingerprint** — the canonical abstract signature (tree
  paths + shapes/dtypes + static argument values) of each entry is hashed
  and pinned in a checked-in manifest.  Drift means the entry's jit cache
  key changed (a new state field, a dtype change, a weak-type literal) —
  exactly the edits that cause silent per-call recompiles at runtime.
  Entries whose runtime signatures legitimately vary (length-bucketed
  prefill) still pin their canonical shape; runtime recompile *counts*
  are watched by the serve CLI telemetry instead.
* **weak-type-signature** — no example-input leaf may carry a weak type:
  a weak-typed scalar in the argument tree retraces against every strong
  dtype it meets.
* **f64 / cache-dtype-drift** — no f64 anywhere in compiled code, and the
  cache tree's leaf dtypes must round-trip the entry unchanged (a silent
  bf16 -> f32 upcast doubles pool bytes).
* **collective-manifest** — under a mesh, the set of collective kinds in
  the compiled module must match the manifest's expected set for that
  (config, entry): an unexpected all-gather under the scan is a silent
  per-step latency cliff.

The kernels triads (fp8_quant / fp8_matmul / scale_search jitted ops) run
the same host-boundary / f64 / fingerprint contracts (donation does not
apply — they consume live weights).
"""
from __future__ import annotations

import hashlib
import re
import warnings
from dataclasses import dataclass, field

from repro.staticcheck.report import Violation

DONATION_MIN_BYTES = 256   # ignore scalar-ish donated leaves (flag bytes
                           # that matter; lengths[B] etc. are noise)

_F64_RE = re.compile(r"\bf64\[")


@dataclass(frozen=True)
class Case:
    """One point of the config matrix."""
    name: str
    arch: str = "glm4-9b"          # dense; mixtral = SWA+MoE,
                                   # mamba2 = SSM, jamba = hybrid
    paged: bool = False
    chunked: bool = False          # chunked prefill (implies paged)
    prefix: bool = False           # prefix cache (implies chunked)
    spec: bool = False             # speculative decoding (implies paged)
    mesh: bool = False             # sharded over a 2-device host mesh
    cache_len: int = 32
    chunk_size: int = 0            # 0 -> engine default when chunked

    def engine_kwargs(self) -> dict:
        paged = self.paged or self.chunked or self.prefix or self.spec
        return dict(slots=2, cache_len=self.cache_len,
                    k_steps=2, paged=paged, block_size=8,
                    chunk_size=(self.chunk_size or (32 if self.chunked
                                                    else 0)),
                    prefix_cache=self.prefix,
                    n_spec=1 if self.spec else 0)


# The reduced matrix CI runs on every push: the dense stack through every
# cache mode, plus one mesh point.  The full matrix adds the other model
# families (SWA ring, MoE, SSM, hybrid) whose cache trees have different
# leaf sets and therefore different donation/dtype surfaces.
QUICK_MATRIX = (
    Case("dense-contig"),
    Case("dense-paged", paged=True),
    Case("dense-prefix", prefix=True),
    Case("dense-spec", spec=True),
    Case("dense-paged-mesh", paged=True, mesh=True),
)
FULL_MATRIX = QUICK_MATRIX + (
    Case("swa-moe-paged", arch="mixtral-8x22b", paged=True),
    Case("ssm-paged", arch="mamba2-780m", paged=True),
    Case("ssm-spec", arch="mamba2-780m", spec=True),
    Case("hybrid-chunked", arch="jamba-v0.1-52b", chunked=True,
         cache_len=64, chunk_size=32),
    # the composed dispatch: speculation x chunked prefill and
    # speculation x prefix cache run both speculative entries (the pure
    # rounds and the rounds + in-scan prefill phase) under the same
    # donation / fingerprint / dtype contracts
    Case("dense-spec-chunked", spec=True, chunked=True),
    Case("dense-spec-prefix", spec=True, prefix=True),
    Case("dense-contig-mesh", mesh=True),
)
MATRICES = {"quick": QUICK_MATRIX, "full": FULL_MATRIX}


def case_entry_names(case: Case) -> tuple[str, ...]:
    """The entries this configuration actually exercises at runtime."""
    if case.spec and (case.chunked or case.prefix):
        return ("_dispatch_spec", "_dispatch_spec_chunk", "_admit_chunk",
                "_evict")
    if case.chunked or case.prefix:
        return ("_dispatch", "_dispatch_chunk", "_admit_chunk", "_evict")
    if case.spec:
        return ("_dispatch_spec", "_scatter_paged", "_prefill_full",
                "_prefill_padded")
    if case.paged:
        return ("_dispatch", "_scatter_paged", "_prefill_full",
                "_prefill_padded")
    return ("_dispatch", "_scatter", "_prefill_full", "_prefill_padded")


# -- engine + example-input construction ------------------------------------

def build_engine(case: Case):
    import jax

    from repro.configs import get_arch, reduced
    from repro.engine import Engine
    from repro.models import build_model

    cfg = reduced(get_arch(case.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft = None
    if case.spec:
        from repro.configs import QuantConfig
        from repro.quantize import quantize
        qcfg = QuantConfig(method="absmax", granularity="channel")
        draft, _ = quantize(params, None, qcfg, mode="storage",
                            out_dtype="bfloat16")
    mesh = None
    if case.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=2)
    return Engine(model, params, mesh=mesh, draft_params=draft,
                  **case.engine_kwargs())


def entry_args(eng, case: Case, name: str) -> tuple:
    """Canonical example inputs matching the runtime call signature of one
    entry point (static arguments included, in position)."""
    import jax
    import jax.numpy as jnp

    from repro.engine import paged as P
    from repro.engine.scheduler import init_slot_state

    cfg, model = eng.cfg, eng.model
    B = cfg.slots
    L = 8                       # canonical example prompt length
    key = jax.random.PRNGKey(0)
    if cfg.paged:
        cache = model.init_paged_cache(B, cfg.cache_len,
                                       block_size=cfg.block_size,
                                       num_blocks=eng._num_blocks)
    else:
        cache = model.init_cache(B, cfg.cache_len)
    if eng.mesh is not None:
        cache = eng._place_cache(cache)
    pcap = cfg.cache_len
    state = init_slot_state(B, prompt_cap=pcap if cfg.chunk_size else 0)

    if name == "_dispatch":
        return (eng.params, state, cache, key)
    if name == "_dispatch_chunk":
        return (eng.params, state, cache, key)
    if name in ("_dispatch_spec", "_dispatch_spec_chunk"):
        # depth is the runtime dynamic-speculation-depth operand: a strong
        # int32 scalar, so every value shares one traced signature (the
        # fingerprint contract pins that no depth move ever recompiles)
        return (eng.params, eng._draft_params, state, cache,
                jnp.int32(1), key)
    if name == "_admit_chunk":
        shared = jnp.full((eng._mb,), -1, jnp.int32)
        toks = jnp.zeros((pcap,), jnp.int32)
        i32 = jnp.int32
        return (cache, state, i32(0), toks, i32(L), shared, i32(0),
                i32(1), i32(0), i32(0), i32(0), i32(1))
    if name == "_evict":
        return (cache, state,
                jnp.full((eng._num_blocks,), -1, jnp.int32))

    # admission entries: the part cache comes from an abstract prefill so
    # no real forward runs during checking
    toks1 = jnp.zeros((1, L), jnp.int32)
    cl = eng._group_cache_len(L)
    if name == "_prefill_full":
        return (eng.params, toks1, cl)
    if name == "_prefill_padded":
        toks2 = jnp.zeros((2, L), jnp.int32)
        lens2 = jnp.asarray([L, L - 3], jnp.int32)
        return (eng.params, toks2, lens2, cl)
    # abstract prefill (static cache_len closed over: eval_shape would
    # otherwise trace it) -> part-cache ShapeDtypeStructs, no forward run
    pf = eng.entry_points()["_prefill_full"]["fun"]
    _, part = jax.eval_shape(lambda p, t: pf(p, t, cl), eng.params, toks1)
    slots = jnp.zeros((1,), jnp.int32)
    first = jax.ShapeDtypeStruct((1,), jnp.int32)
    rem0 = jnp.int32(7)
    if name == "_scatter":
        return (cache, state, part, slots, first, rem0)
    if name == "_scatter_paged":
        lens = jnp.asarray([L], jnp.int32)
        if model.cfg.sliding_window:
            counts = jnp.full((1,), eng._mb, jnp.int32)
        else:
            counts = jnp.asarray(
                [min(P.blocks_for(L, cfg.block_size), eng._mb)], jnp.int32)
        return (cache, state, part, slots, lens, first, rem0, counts)
    raise KeyError(f"no example inputs for entry {name!r}")


# -- contract checks --------------------------------------------------------

def _dynamic_args(args: tuple, static_argnums: tuple) -> list:
    return [a for i, a in enumerate(args) if i not in static_argnums]


def _abstractify(leaf):
    """Aval of a leaf, or None for non-array statics riding in a tree."""
    import jax

    try:
        return jax.api_util.shaped_abstractify(leaf)
    except (TypeError, ValueError):
        return None


def signature_fingerprint(args: tuple, static_argnums: tuple) -> str:
    """Stable hash of the abstract calling signature: flattened tree paths
    with shape/dtype/weak-type per dynamic leaf, plus static values."""
    import jax

    from repro.core.policy import path_str

    lines = []
    for i, a in enumerate(args):
        if i in static_argnums:
            lines.append(f"static[{i}]={a!r}")
            continue
        flat = jax.tree_util.tree_flatten_with_path(a)[0]
        for path, leaf in flat:
            aval = _abstractify(leaf)
            desc = (f"{aval.str_short()}{'*' if aval.weak_type else ''}"
                    if aval is not None else f"py:{leaf!r}")
            lines.append(f"arg[{i}]/{path_str(path)}:{desc}")
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()[:16]


def weak_type_leaves(args: tuple, static_argnums: tuple) -> list[str]:
    import jax

    from repro.core.policy import path_str

    out = []
    for i, a in enumerate(args):
        if i in static_argnums:
            continue
        for path, leaf in jax.tree_util.tree_flatten_with_path(a)[0]:
            aval = _abstractify(leaf)
            if aval is not None and aval.weak_type:
                out.append(f"arg[{i}]/{path_str(path)}")
    return out


def donated_leaf_params(args: tuple, donate: tuple,
                        static_argnums: tuple) -> list[tuple[int, str, int]]:
    """(entry param number, tree path, nbytes) of every donated leaf.
    Entry parameters of a jitted module are the flattened dynamic
    arguments in order."""
    import jax
    import numpy as np

    from repro.core.policy import path_str

    out = []
    p = 0
    dyn_index = -1
    for i, a in enumerate(args):
        if i in static_argnums:
            continue
        dyn_index += 1
        flat = jax.tree_util.tree_flatten_with_path(a)[0]
        for path, leaf in flat:
            if i in donate:
                aval = _abstractify(leaf)
                nbytes = int(np.prod(aval.shape, dtype=np.int64)
                             * aval.dtype.itemsize) if aval else 0
                out.append((p, f"arg[{i}]/{path_str(path)}", nbytes))
            p += 1
    return out


@dataclass
class EntryCheck:
    """Result of checking one (case, entry)."""
    violations: list[Violation] = field(default_factory=list)
    fingerprint: str = ""
    collectives: list[str] = field(default_factory=list)
    n_params: int = 0


def check_entry(case_name: str, entry_name: str, rec: dict, args: tuple,
                *, expect: dict | None, update: bool,
                mesh: bool = False, check_donation: bool = True,
                cache_in=None) -> EntryCheck:
    """Lower + compile one registered entry and run every contract."""
    import jax

    from repro.analysis.hlo import HloModule

    res = EntryCheck()
    where = f"{case_name}/{entry_name}"
    statics = rec.get("static_argnums", ())
    donate = rec.get("donate", ())

    # (c) recompile fingerprint + weak-type hygiene -------------------------
    res.fingerprint = signature_fingerprint(args, statics)
    for leaf in weak_type_leaves(args, statics):
        res.violations.append(Violation(
            kind="contract", rule="weak-type-signature", where=where,
            symbol=leaf,
            msg=f"{leaf} carries a weak type: the jit cache keys on weak "
                f"types, so this leaf retraces against every strong dtype "
                f"it meets"))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # alias table is the truth source
        compiled = rec["fn"].lower(*args).compile()
    txt = compiled.as_text()
    mod = HloModule(txt)
    res.n_params = len(mod.entry_params())

    # (a) donation landed ---------------------------------------------------
    if check_donation and donate:
        aliased = mod.aliased_param_numbers()
        for pnum, path, nbytes in donated_leaf_params(args, donate, statics):
            if nbytes < DONATION_MIN_BYTES or pnum in aliased:
                continue
            res.violations.append(Violation(
                kind="contract", rule="donation-not-landed", where=where,
                symbol=path, bytes_wasted=nbytes,
                msg=f"donated operand {path} ({nbytes} bytes) has no "
                    f"input_output_alias entry: XLA copied the buffer "
                    f"instead of reusing it — the pool is paid for twice "
                    f"at every call"))

    # (b) no host boundary in traced code -----------------------------------
    for comp, op, target in mod.host_ops():
        detail = f" target={target}" if target else ""
        res.violations.append(Violation(
            kind="contract", rule="host-boundary", where=where,
            symbol=f"{comp}:{op}",
            msg=f"host-crossing op {op}{detail} in computation {comp}: a "
                f"host sync inside traced code serializes every call "
                f"(inside the K-step scan: once per token)"))

    # (d) dtype hygiene -----------------------------------------------------
    if _F64_RE.search(txt):
        res.violations.append(Violation(
            kind="contract", rule="f64", where=where, symbol="module",
            msg="f64 buffers in compiled code (an accidental float64 "
                "promotion — jax_enable_x64 leak or numpy scalar)"))
    if cache_in is not None and rec.get("cache_out") is not None:
        from repro.core.policy import path_str
        out = jax.eval_shape(rec["fn"], *args)
        out_cache = (out[rec["cache_out"]]
                     if isinstance(out, (tuple, list)) else out)
        in_d = {path_str(p): l.dtype for p, l in
                jax.tree_util.tree_flatten_with_path(cache_in)[0]}
        out_d = {path_str(p): l.dtype for p, l in
                 jax.tree_util.tree_flatten_with_path(out_cache)[0]}
        for k, dt_in in in_d.items():
            dt_out = out_d.get(k)
            if dt_out is not None and dt_out != dt_in:
                res.violations.append(Violation(
                    kind="contract", rule="cache-dtype-drift", where=where,
                    symbol=k,
                    msg=f"cache leaf {k} enters {dt_in} but leaves "
                        f"{dt_out}: a silent upcast grows the pool "
                        f"every dispatch"))

    # (e) collective manifest ----------------------------------------------
    if mesh:
        nd = len(jax.devices())
        counts = mod.collectives(nd)["counts"]
        res.collectives = sorted(counts)

    # fingerprint / collectives vs the checked-in manifest ------------------
    if expect is None:
        if not update:
            res.violations.append(Violation(
                kind="contract", rule="fingerprint-missing", where=where,
                symbol="manifest",
                msg="entry has no manifest record: run `python -m "
                    "repro.staticcheck --update` and commit the manifest"))
    else:
        if expect.get("fingerprint") != res.fingerprint:
            res.violations.append(Violation(
                kind="contract", rule="recompile-fingerprint", where=where,
                symbol="signature",
                msg=f"abstract signature drifted "
                    f"({expect.get('fingerprint')} -> {res.fingerprint}): "
                    f"the entry's jit cache key changed — audit for "
                    f"shape/dtype/state-tree drift, then `--update` the "
                    f"manifest deliberately"))
        if mesh and expect.get("collectives") is not None \
                and expect["collectives"] != res.collectives:
            res.violations.append(Violation(
                kind="contract", rule="collective-manifest", where=where,
                symbol="collectives",
                msg=f"collective set changed: expected "
                    f"{expect['collectives']}, compiled "
                    f"{res.collectives}"))
    return res


def check_case(case: Case, manifest: dict, update: bool):
    """All entries of one matrix case.  Returns (violations, manifest
    records, entries checked)."""
    eng = build_engine(case)
    entries = eng.entry_points()
    violations: list[Violation] = []
    records: dict[str, dict] = {}
    for name in case_entry_names(case):
        rec = entries[name]
        args = entry_args(eng, case, name)
        cache_in = (args[rec["cache_arg"]]
                    if rec.get("cache_arg") is not None else None)
        expect = manifest.get(case.name, {}).get(name)
        res = check_entry(case.name, name, rec, args, expect=expect,
                          update=update, mesh=case.mesh,
                          cache_in=cache_in)
        violations.extend(res.violations)
        records[name] = {"fingerprint": res.fingerprint}
        if case.mesh:
            records[name]["collectives"] = res.collectives
    return violations, records, len(records)


# -- kernels triads ---------------------------------------------------------

def kernel_entries() -> dict[str, tuple]:
    """(jitted op, args, static kwargs) for the Pallas kernel wrappers —
    interpret mode, CPU-checkable."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fp8_matmul.ops import matmul_fp8_2d
    from repro.kernels.fp8_quant.ops import quantize_fp8
    from repro.kernels.scale_search.ops import sweep

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 64), jnp.float32)
    alpha = jnp.float32(1.0)
    q, s = jax.eval_shape(
        lambda w_, a_: quantize_fp8(w_, a_, block=32, interpret=True),
        w, alpha)
    x = jnp.zeros((8, 64), jnp.float32)
    alphas = jnp.linspace(0.8, 1.25, 4)
    return {
        "fp8_quant.quantize_fp8": (
            quantize_fp8, (w, alpha), {"block": 32, "interpret": True}),
        "fp8_matmul.matmul_fp8_2d": (
            matmul_fp8_2d,
            (x, jax.ShapeDtypeStruct(q.shape, q.dtype),
             jax.ShapeDtypeStruct(s.shape, s.dtype)),
            {"block": 32, "interpret": True}),
        "scale_search.sweep": (
            sweep, (w, w, alphas),
            {"block_size": 32, "use_kernel": True, "interpret": True}),
    }


def check_kernels(manifest: dict, update: bool):
    """Host-boundary / f64 / fingerprint contracts over the kernel triads
    (donation does not apply: the ops consume live weights)."""
    violations: list[Violation] = []
    records: dict[str, dict] = {}
    for name, (fn, args, kwargs) in kernel_entries().items():
        pairs = tuple(sorted(kwargs.items()))
        # the static kwargs ride as trailing positional (key, value) pairs
        # marked static, so the fingerprint records them by repr instead of
        # abstractifying them (bools/ints would read as weak-typed leaves)
        rec = {"fn": _KwargsLower(fn, kwargs), "donate": (),
               "static_argnums": tuple(range(len(args),
                                             len(args) + len(pairs))),
               "cache_out": None}
        expect = manifest.get("kernels", {}).get(name)
        res = check_entry("kernels", name, rec, args + pairs,
                          expect=expect, update=update,
                          check_donation=False)
        violations.extend(res.violations)
        records[name] = {"fingerprint": res.fingerprint}
    return violations, records, len(records)


class _KwargsLower:
    """Adapter: check_entry lowers positionally; kernel ops take their
    static switches as keywords.  The trailing (key, value) pairs in the
    args tuple (marked static for the fingerprint) are stripped back to
    kwargs here."""

    def __init__(self, fn, kwargs):
        self._fn = fn
        self._kwargs = kwargs

    def lower(self, *args):
        n = len(self._kwargs)
        real = args[:-n] if n else args
        return self._fn.lower(*real, **self._kwargs)


def run_contracts(matrix: str, manifest: dict, update: bool):
    """Run the whole pass.  Returns (violations, new manifest, counters,
    skipped case names)."""
    import jax

    cases = MATRICES[matrix]
    violations: list[Violation] = []
    new_manifest: dict = {}
    skipped: list[str] = []
    n_entries = 0
    for case in cases:
        if case.mesh and len(jax.devices()) < 2:
            skipped.append(
                f"{case.name}: needs >= 2 devices (run via `python -m "
                f"repro.staticcheck`, which forces a 2-device host "
                f"platform)")
            # keep the manifest records so --update on a 1-device host
            # does not erase the mesh expectations
            if case.name in manifest:
                new_manifest[case.name] = manifest[case.name]
            continue
        vs, records, n = check_case(case, manifest, update)
        violations.extend(vs)
        new_manifest[case.name] = records
        n_entries += n
    kvs, krecords, kn = check_kernels(manifest, update)
    violations.extend(kvs)
    new_manifest["kernels"] = krecords
    n_entries += kn
    counters = {"cases": len(cases) - len(skipped), "entries": n_entries}
    return violations, new_manifest, counters, skipped
