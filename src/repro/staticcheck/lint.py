"""AST lint: repo-specific hazards jit hides until they cost 100x.

Three rules, all scoped to where they are actually bugs:

* ``host-sync`` — ``jax.device_get`` / ``.item()`` / ``np.asarray`` inside
  *traced* code: the engine's scan-body modules (scheduler/spec/paged/
  sampler), the model stack, and the ``*_impl`` jitted bodies in
  ``engine/engine.py``.  One of these inside the K-step scan reintroduces
  the per-token host round-trip the dispatch exists to remove.  Host-side
  admission/drain code is exempt by construction (it is not in the traced
  set); a traced function that legitimately crosses the boundary can carry
  ``# staticcheck: host-boundary`` on its ``def`` line.
* ``list-asarray`` — ``jnp.asarray([...])`` / ``jnp.array([...])`` of a
  Python list/tuple literal in traced code: the literal re-materializes
  (and, element-wise weak-typed, re-*traces*) per call.
* ``undonated-jit`` — a ``jax.jit`` call (or ``partial(jax.jit, ...)``
  decorator) whose wrapped callable takes a cache/pool-shaped argument
  (``cache``/``state``/``bstate``/``pool``/``part_cache``) without
  ``donate_argnums``: the cache buffer is silently duplicated at every
  call (2x cache memory).  Applies repo-wide.

Suppression: ``# staticcheck: ok[rule]`` (or bare ``# staticcheck: ok``)
on the flagged line waives it in place — prefer this over a baseline
entry when the code is *correct*, so the reason lives next to the code.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.staticcheck.report import Violation

# Modules whose entire body is traced (runs under jit/scan).  engine.py is
# mixed host/device: only its ``*_impl`` functions are traced there.
TRACED_FILES = (
    "engine/scheduler.py",
    "engine/spec.py",
    "engine/paged.py",
    "engine/sampler.py",
    "quant_runtime/qlinear.py",
    "telemetry/counters.py",
)
TRACED_DIRS = ("models/",)
MIXED_FILES = ("engine/engine.py",)

CACHE_PARAMS = {"cache", "state", "bstate", "pool", "part_cache"}

_OK_RE = re.compile(r"#\s*staticcheck:\s*ok(?:\[([\w,\s-]*)\])?")
_HOST_RE = re.compile(r"#\s*staticcheck:\s*host-boundary")


def _is_traced_file(rel: str) -> bool:
    return rel in TRACED_FILES or any(rel.startswith(d)
                                      for d in TRACED_DIRS)


def _pragma_ok(lines: list[str], lineno: int, rule: str) -> bool:
    if not (1 <= lineno <= len(lines)):
        return False
    m = _OK_RE.search(lines[lineno - 1])
    if not m:
        return False
    rules = m.group(1)
    return rules is None or rule in {r.strip() for r in rules.split(",")}


def _call_name(node: ast.AST) -> str:
    """Dotted name of a call target: ``jax.device_get`` -> that string."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _jit_wrapped_params(call: ast.Call, module: ast.Module) -> set[str]:
    """Parameter names of the callable handed to a ``jax.jit(...)`` call."""
    if not call.args:
        return set()
    fn = call.args[0]
    if isinstance(fn, ast.Lambda):
        return {a.arg for a in fn.args.args}
    name = None
    if isinstance(fn, ast.Name):
        name = fn.id
    elif isinstance(fn, ast.Attribute):   # self._admit_chunk_impl etc.
        name = fn.attr
    if name is None:
        return set()
    for node in ast.walk(module):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return {a.arg for a in node.args.args} - {"self", "cls"}
    return set()


def _has_donate(call: ast.Call) -> bool:
    return any(kw.arg in ("donate_argnums", "donate_argnames")
               for kw in call.keywords)


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, lines: list[str], module: ast.Module):
        self.rel = rel
        self.lines = lines
        self.module = module
        self.violations: list[Violation] = []
        self._func_stack: list[tuple[str, bool]] = []  # (name, host_ok)
        self.traced_file = _is_traced_file(rel)
        self.mixed_file = rel in MIXED_FILES

    # -- scope helpers ----------------------------------------------------

    def _in_traced_code(self) -> bool:
        if any(host for _, host in self._func_stack):
            return False
        if self.traced_file:
            return True
        if self.mixed_file:
            return any(name.endswith("_impl")
                       for name, _ in self._func_stack)
        return False

    def _fname(self) -> str:
        return self._func_stack[-1][0] if self._func_stack else "<module>"

    def _emit(self, rule: str, node: ast.AST, symbol: str, msg: str):
        if _pragma_ok(self.lines, node.lineno, rule):
            return
        self.violations.append(Violation(
            kind="lint", rule=rule, where=self.rel, symbol=symbol,
            msg=msg, line=node.lineno))

    # -- visitors ---------------------------------------------------------

    def visit_FunctionDef(self, node):
        host = bool(_HOST_RE.search(self.lines[node.lineno - 1])) \
            if node.lineno <= len(self.lines) else False
        self._func_stack.append((node.name, host))
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        name = _call_name(node.func)
        if self._in_traced_code():
            if name in ("jax.device_get", "np.asarray", "numpy.asarray",
                        "onp.asarray"):
                self._emit("host-sync", node, self._fname(),
                           f"{name}() in traced code forces a device->host "
                           f"sync every call (inside the K-step scan: one "
                           f"per token)")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "item" and not node.args):
                self._emit("host-sync", node, self._fname(),
                           ".item() in traced code forces a device->host "
                           "sync every call")
            if name in ("jnp.asarray", "jnp.array", "jax.numpy.asarray",
                        "jax.numpy.array") and node.args \
                    and isinstance(node.args[0], (ast.List, ast.Tuple)):
                self._emit("list-asarray", node, self._fname(),
                           f"{name}() of a Python list literal in traced "
                           f"code re-materializes a constant per call "
                           f"(and weak-typed literals re-trace)")
        if name == "jax.jit" and not _has_donate(node):
            params = _jit_wrapped_params(node, self.module)
            hit = sorted(params & CACHE_PARAMS)
            if hit:
                self._emit("undonated-jit", node, self._fname(),
                           f"jax.jit of a callable taking {hit} without "
                           f"donate_argnums: the cache/pool buffer is "
                           f"copied, not reused (2x memory per call)")
        self.generic_visit(node)

    def visit_FunctionDef_decorators(self, node):  # pragma: no cover
        pass


def _lint_decorated_jits(tree: ast.Module, rel: str, lines: list[str],
                         out: list[Violation]) -> None:
    """``@partial(jax.jit, ...)``-decorated defs with cache-shaped params
    and no donation."""
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if not (isinstance(dec, ast.Call)
                    and _call_name(dec.func) == "partial"
                    and dec.args
                    and _call_name(dec.args[0]) == "jax.jit"):
                continue
            if _has_donate(dec):
                continue
            params = {a.arg for a in node.args.args} - {"self", "cls"}
            hit = sorted(params & CACHE_PARAMS)
            if hit and not _pragma_ok(lines, dec.lineno, "undonated-jit") \
                    and not _pragma_ok(lines, node.lineno, "undonated-jit"):
                out.append(Violation(
                    kind="lint", rule="undonated-jit", where=rel,
                    symbol=node.name, line=node.lineno,
                    msg=f"partial(jax.jit)-decorated {node.name} takes "
                        f"{hit} without donate_argnums"))


def lint_file(path: Path, rel: str) -> list[Violation]:
    src = path.read_text()
    lines = src.splitlines()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation(kind="lint", rule="syntax", where=rel,
                          symbol="<module>", line=e.lineno or 0,
                          msg=f"unparseable: {e.msg}")]
    linter = _Linter(rel, lines, tree)
    linter.visit(tree)
    _lint_decorated_jits(tree, rel, lines, linter.violations)
    return linter.violations


def lint_tree(root: str | Path) -> tuple[list[Violation], int]:
    """Lint every ``.py`` under ``root``; returns (violations, n_files)."""
    root = Path(root)
    violations: list[Violation] = []
    n = 0
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        n += 1
        violations.extend(lint_file(path, rel))
    return violations, n
