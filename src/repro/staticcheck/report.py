"""Violation records, JSON reports, and the baseline ratchet.

A violation's identity is its ``key`` — ``kind:rule:where:symbol`` — which
deliberately excludes line numbers and prose so unrelated edits don't churn
the baseline.  The checked-in baseline (``staticcheck_baseline.json``)
lists the *waived* keys with their full records for review; a run fails
when it produces any violation whose key is not waived.  The ratchet only
goes down: waivers that no longer fire are reported as stale (drop them
with ``--update``), and new violations never pass silently.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

BASELINE_VERSION = 1


@dataclass(frozen=True)
class Violation:
    kind: str              # "lint" | "contract"
    rule: str              # e.g. "host-sync", "donation-not-landed"
    where: str             # file path (lint) or "case/entry" (contract)
    symbol: str            # enclosing function / contract anchor
    msg: str
    line: int = 0          # advisory only — not part of the identity key
    bytes_wasted: int = 0  # donation contract: buffer paid for twice

    @property
    def key(self) -> str:
        return f"{self.kind}:{self.rule}:{self.where}:{self.symbol}"

    def row(self) -> dict:
        d = asdict(self)
        d["key"] = self.key
        return d


@dataclass
class Report:
    violations: list[Violation] = field(default_factory=list)
    checked: dict = field(default_factory=dict)   # counters per pass
    skipped: list[str] = field(default_factory=list)

    def extend(self, vs) -> None:
        self.violations.extend(vs)

    def to_json(self) -> dict:
        return {
            "version": BASELINE_VERSION,
            "checked": self.checked,
            "skipped": self.skipped,
            "violations": [v.row() for v in self.violations],
            "bytes_wasted": sum(v.bytes_wasted for v in self.violations),
        }


def load_baseline(path) -> dict:
    """``{key: waiver-record}`` from a baseline file; {} when absent."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{data.get('version')!r}")
    return {w["key"]: w for w in data.get("waivers", [])}


def write_baseline(path, violations: list[Violation]) -> None:
    """Rewrite the baseline to waive exactly the current violations."""
    data = {
        "version": BASELINE_VERSION,
        "comment": "Explicit waivers for repro.staticcheck — every entry "
                   "is a known, reviewed violation.  The ratchet only "
                   "goes down: remove entries as they are fixed, never "
                   "add one without a reason in its record.",
        "waivers": sorted((v.row() for v in violations),
                          key=lambda r: r["key"]),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def diff_baseline(violations: list[Violation], baseline: dict):
    """(new, waived, stale): violations not in the baseline, violations
    covered by it, and waiver keys that no longer fire."""
    seen = {v.key for v in violations}
    new = [v for v in violations if v.key not in baseline]
    waived = [v for v in violations if v.key in baseline]
    stale = sorted(k for k in baseline if k not in seen)
    return new, waived, stale
