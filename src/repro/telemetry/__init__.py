"""Observability for the serving engine — three layers, all free on the
hot path:

* **Device-resident counters** (:mod:`repro.telemetry.counters`): a small
  int32 pytree (``state["ctr"]``) threaded through the dispatch scan carry
  by ``engine/scheduler.py`` / ``engine/spec.py`` and bumped where the
  events happen (token emission, block pops/releases, CoW copies, prefix
  hits, chunk pieces, blocked speculative slots).  The counters ride the
  donated state tree, so they are read *for free* at the once-per-K host
  sync the engine already pays — zero new host syncs, zero recompiles
  (pinned by the staticcheck fingerprint manifest and a compile-count
  test).
* **Request-lifecycle metrics** (:mod:`repro.telemetry.metrics`): a
  host-side :class:`MetricsRegistry` of counters, gauges and log-bucketed
  histograms — per-request TTFT / TPOT / queue-wait / lengths, acceptance
  rate, prefix-hit fraction, allocator gauges — snapshotted to a stable
  JSON schema (``repro.telemetry.metrics/v1``) and summarized
  (p50/p95/p99) by the serve CLI.
* **Trace export** (:mod:`repro.telemetry.trace`): a :class:`Tracer`
  emitting Chrome/Perfetto trace-event JSON — one track per subsystem
  (admission, dispatch, speculative rounds with depth annotations,
  prefill chunks, eviction) plus counter tracks sampled from the device
  counters.  Open the file in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing``.

Enable the host-side layers with ``Engine(..., metrics=MetricsRegistry(),
tracer=Tracer())`` or ``python -m repro.launch.serve --metrics-out PATH
--trace-out PATH``; the device counters are always on (a handful of
scalar adds inside the scan) and surface as ``stats["counters"]``.
"""
from repro.telemetry.counters import (COUNTER_KEYS, bump, counter_totals,
                                      init_counters)
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, METRICS_SCHEMA)
from repro.telemetry.trace import TRACE_PID, Tracer

__all__ = [
    "COUNTER_KEYS", "init_counters", "bump", "counter_totals",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "METRICS_SCHEMA",
    "Tracer", "TRACE_PID",
]
