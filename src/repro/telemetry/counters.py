"""Device-resident engine counters: an int32 pytree riding the scan carry.

The counters live in the slot-state tree (``state["ctr"]``, created by
``scheduler.init_slot_state``) and are bumped *inside* the jitted dispatch
at the point each event happens — the scan body in ``scheduler.py``, the
speculative round in ``spec.py``, and the jitted admission/eviction
entries in ``engine.py`` (allocator pops/releases are measured as
``n_free`` / ``ref`` deltas around the ``paged.py`` primitives).  Because
the state tree is already returned — and donated — at every dispatch
boundary, the host reads the counters in the same ``device_get`` that
drains the token grid: **zero** additional host syncs, and the only
compile-side effect is the state tree growing a few scalar leaves (a
deliberate, manifest-updated fingerprint change).

Counters are cumulative int32 scalars, zeroed at the start of each
``Engine.serve`` call; the engine exposes them as ``stats["counters"]``
and derives per-dispatch deltas host-side (the DepthController's
drafted/accepted feed).

Conservation identities (asserted under ``check_invariants=True`` and in
the hypothesis stress sweeps):

* ``drafted == accepted + rejected`` — every drafted position is either
  part of the verifier-agreement prefix or rolled back;
* ``blocks_popped - blocks_released == num_blocks - n_free`` — pops and
  releases account for every block currently out of the free stack
  ("popped == released + live").
"""
from __future__ import annotations

import jax.numpy as jnp

# One int32 scalar per key.  Every mutation site is listed next to its key.
COUNTER_KEYS = (
    "tokens",            # tokens emitted through the dispatch grids
                         # (decode emissions, speculative emissions, and
                         # first tokens of in-scan prefill completions;
                         # batched-prefill first tokens are host-side)
    "drafted",           # spec: draft positions proposed (depth x active)
    "accepted",          # spec: verifier-agreement prefix lengths summed
    "rejected",          # spec: drafted - accepted (rolled-back positions)
    "blocks_popped",     # pool blocks popped (decode alloc, span alloc,
                         # admission alloc — includes CoW pops)
    "blocks_released",   # pool blocks pushed back on the free stack
                         # (slot drains, zero-budget releases, eviction)
    "cow_copies",        # copy-on-write pops (a write into a shared block
                         # popped a private copy first)
    "prefix_hit_tokens", # prompt tokens served from the prefix cache —
                         # counted at admission as pf_start, the tokens
                         # actually skipped (== host stats["prefix_hits"])
    "chunk_pieces",      # in-scan prefill chunk pieces run
    "chunks_completed",  # prompts that finished in-scan prefill
    "blocked_retries",   # spec slots masked out of a round (CoW pop
                         # failed, pool dry) — they retry next round
)


def init_counters() -> dict:
    """Zeroed counter pytree — strong int32 scalars (a weak-typed literal
    here would retrace every dispatch; see staticcheck weak-type rule)."""
    return {k: jnp.zeros((), jnp.int32) for k in COUNTER_KEYS}


def bump(ctr: dict, **deltas) -> dict:
    """Counters with ``deltas`` added (jit-safe; values are cast to int32
    so bool sums and traced scalars accumulate without dtype drift)."""
    out = dict(ctr)
    for k, d in deltas.items():
        out[k] = out[k] + jnp.asarray(d, jnp.int32)
    return out


def counter_totals(ctr_host: dict) -> dict:
    """Host-side view of a fetched counter tree as plain ints, in
    COUNTER_KEYS order (stable for snapshots and stats)."""
    return {k: int(ctr_host[k]) for k in COUNTER_KEYS}
