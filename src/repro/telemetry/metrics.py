"""Host-side request-lifecycle metrics: counters, gauges, histograms.

Everything here runs on the host, outside jit, fed by values the engine
already fetched (dispatch results, host mirrors, the device counter
tree) — recording a metric never adds a device sync.  The registry
snapshots to a stable JSON schema (:data:`METRICS_SCHEMA`) so artifacts
from different commits diff cleanly, and summarizes to the p50/p95/p99
lines the serve CLI prints.

Histograms use **fixed log-spaced buckets**: ``n_buckets`` edges spanning
``[lo, hi)`` at a constant ratio, plus an underflow and an overflow
bucket, so two runs of the same histogram are bucket-compatible by
construction.  Exact observations are retained as well (one float per
``observe``; request-scale cardinality), so the exported percentiles are
exact nearest-rank values, not bucket interpolations — the buckets exist
for cross-run diffing and trace counter tracks.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

METRICS_SCHEMA = "repro.telemetry.metrics/v1"


@dataclass
class Counter:
    """Monotonic count of events."""
    name: str
    value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)


@dataclass
class Gauge:
    """Last-written value (None until first set)."""
    name: str
    value: float | None = None

    def set(self, v: float) -> None:
        self.value = float(v)


def log_bucket_edges(lo: float, hi: float, n_buckets: int) -> list[float]:
    """``n_buckets + 1`` log-spaced edges: ``edges[i] = lo * (hi/lo)^(i/n)``
    — ``edges[0] == lo``, ``edges[n] == hi`` (up to float rounding, pinned
    exactly at both ends)."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
    if n_buckets < 1:
        raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
    ratio = hi / lo
    edges = [lo * ratio ** (i / n_buckets) for i in range(n_buckets + 1)]
    edges[0], edges[-1] = lo, hi
    return edges


@dataclass
class Histogram:
    """Log-bucketed histogram with exact retained observations.

    ``bucket_counts`` has ``n_buckets + 2`` entries: ``[underflow (< lo),
    bucket 0 .. n-1, overflow (>= hi)]``.
    """
    name: str
    lo: float = 1e-4
    hi: float = 1e3
    n_buckets: int = 32
    unit: str = ""
    edges: list[float] = field(init=False)
    bucket_counts: list[int] = field(init=False)
    _samples: list[float] = field(init=False, default_factory=list)

    def __post_init__(self):
        self.edges = log_bucket_edges(self.lo, self.hi, self.n_buckets)
        self.bucket_counts = [0] * (self.n_buckets + 2)

    def observe(self, v: float) -> None:
        v = float(v)
        self._samples.append(v)
        if v < self.lo:
            self.bucket_counts[0] += 1
        elif v >= self.hi:
            self.bucket_counts[-1] += 1
        else:
            # constant-ratio buckets: the index is a single log
            i = int(math.log(v / self.lo)
                    / math.log(self.hi / self.lo) * self.n_buckets)
            i = min(max(i, 0), self.n_buckets - 1)
            # float rounding at an edge can land one bucket off; nudge
            if v < self.edges[i]:
                i -= 1
            elif v >= self.edges[i + 1]:
                i += 1
            self.bucket_counts[1 + i] += 1

    @property
    def count(self) -> int:
        return len(self._samples)

    def percentile(self, q: float) -> float | None:
        """Exact nearest-rank percentile (``q`` in (0, 100]); None when
        empty."""
        if not self._samples:
            return None
        s = sorted(self._samples)
        rank = max(1, math.ceil(q / 100.0 * len(s)))
        return s[rank - 1]

    def to_dict(self) -> dict:
        out = {
            "unit": self.unit,
            "edges": self.edges,
            "counts": list(self.bucket_counts),
            "count": self.count,
        }
        if self._samples:
            out.update(
                sum=float(sum(self._samples)),
                min=float(min(self._samples)),
                max=float(max(self._samples)),
                p50=self.percentile(50),
                p95=self.percentile(95),
                p99=self.percentile(99),
            )
        return out


class MetricsRegistry:
    """Get-or-create registry of named metrics with a stable snapshot.

    One registry typically lives across a CLI run or a benchmark; the
    engine records into it when passed as ``Engine(..., metrics=reg)``.
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge(name))

    def histogram(self, name: str, *, lo: float = 1e-4, hi: float = 1e3,
                  n_buckets: int = 32, unit: str = "") -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = Histogram(name, lo=lo, hi=hi, n_buckets=n_buckets,
                          unit=unit)
            self._histograms[name] = h
        return h

    def snapshot(self) -> dict:
        """Stable-schema dict (sorted keys, plain JSON types) — the single
        source of truth the serve CLI summary and BENCH artifacts embed."""
        return {
            "schema": METRICS_SCHEMA,
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.to_dict()
                           for k, h in sorted(self._histograms.items())},
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")

    def summary(self) -> str:
        """Human-readable p50/p95/p99 lines for the serve CLI."""
        lines = []
        for name, h in sorted(self._histograms.items()):
            if not h.count:
                continue
            unit = f" {h.unit}" if h.unit else ""
            lines.append(
                f"  {name}: p50={h.percentile(50):.4g} "
                f"p95={h.percentile(95):.4g} "
                f"p99={h.percentile(99):.4g}{unit} (n={h.count})")
        for name, g in sorted(self._gauges.items()):
            val = "n/a" if g.value is None else f"{g.value:.4g}"
            lines.append(f"  {name}: {val}")
        for name, c in sorted(self._counters.items()):
            lines.append(f"  {name}: {c.value}")
        return "\n".join(lines)
