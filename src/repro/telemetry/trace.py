"""Chrome/Perfetto trace export for the serving engine.

:class:`Tracer` records trace events host-side (timestamps from
``time.perf_counter`` relative to tracer construction, in microseconds —
the Chrome trace-event clock unit) and serializes them in the Chrome
trace-event JSON-object format::

    {"traceEvents": [...], "displayTimeUnit": "ms"}

Open the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  One *track* (a named tid under one engine pid) per
subsystem — the engine uses ``admission``, ``dispatch``, ``spec``,
``prefill-chunk`` and ``eviction`` — plus counter tracks ("C" events)
sampled from the device counter tree after each dispatch.  Recording an
event is an O(1) list append of values already on the host; the tracer
never touches the device.
"""
from __future__ import annotations

import json
import time

TRACE_PID = 1   # one "process": the engine


class Tracer:
    """Host-side Chrome trace-event recorder.

    Events within a track are recorded in wall order with a monotonic
    clock, so per-track ``ts`` is non-decreasing (a schema property the
    tests pin).  Duration ("X") events take their start from
    :meth:`now_us`, captured by the caller before the spanned work.
    """

    def __init__(self):
        self._t0 = time.perf_counter()
        self._tids: dict[str, int] = {}
        self.events: list[dict] = []

    def now_us(self) -> float:
        """Microseconds since tracer construction (trace clock)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": TRACE_PID,
                "tid": tid, "args": {"name": track},
            })
        return tid

    def complete(self, track: str, name: str, start_us: float,
                 args: dict | None = None) -> None:
        """A duration ("X") event spanning ``start_us`` .. now."""
        now = self.now_us()
        ev = {
            "name": name, "ph": "X", "pid": TRACE_PID,
            "tid": self._tid(track), "ts": start_us,
            "dur": max(now - start_us, 0.0),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, track: str, name: str,
                args: dict | None = None) -> None:
        """An instant ("i") event at the current time."""
        ev = {
            "name": name, "ph": "i", "pid": TRACE_PID,
            "tid": self._tid(track), "ts": self.now_us(), "s": "t",
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: dict) -> None:
        """A counter ("C") sample: ``values`` are series-name -> number,
        rendered by the viewer as a stacked area track."""
        self.events.append({
            "name": name, "ph": "C", "pid": TRACE_PID,
            "ts": self.now_us(),
            "args": {k: float(v) for k, v in values.items()},
        })

    def to_dict(self) -> dict:
        return {
            "traceEvents": [
                {"name": "process_name", "ph": "M", "pid": TRACE_PID,
                 "args": {"name": "repro.engine"}},
                *self.events,
            ],
            "displayTimeUnit": "ms",
        }

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
            f.write("\n")
