"""Test bootstrap.

The property-based tests use ``hypothesis`` when it is installed.  Some CI
containers ship without it; to keep the tier-1 suite runnable everywhere we
install a minimal deterministic fallback into ``sys.modules`` before test
modules import.  The fallback draws a fixed number of pseudo-random examples
from a seeded RNG — strictly weaker than real hypothesis (no shrinking, no
example database) but it executes the same test bodies.
"""
from __future__ import annotations

import random
import sys
import types

try:  # pragma: no cover - prefer the real library when available
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    def _integers(min_value=0, max_value=100):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _settings(max_examples=100, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def _given(*strats, **kwstrats):
        def deco(fn):
            # NOTE: the wrapper must present a ZERO-ARG signature (and no
            # __wrapped__) or pytest treats the strategy params as fixtures.
            def runner():
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = random.Random(fn.__name__)
                for _ in range(n):
                    vals = [s.example(rng) for s in strats]
                    kvals = {k: s.example(rng) for k, s in kwstrats.items()}
                    fn(*vals, **kvals)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.sampled_from = _sampled_from
    strategies.floats = _floats
    strategies.booleans = _booleans
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
