"""Seeded-violation fixture for the staticcheck self-test.

This file deliberately contains every lint hazard; it lives under a
fixture tree whose layout mirrors ``src/repro`` so the path-scoped rules
fire (this relative path is a traced module).  tests/test_staticcheck.py
asserts the checker FAILS on this tree — if a rule regresses to silence,
that test catches it.
"""
import jax
import jax.numpy as jnp
from functools import partial


def step_body(state, cache, x):
    lens = jax.device_get(cache["lengths"])          # host-sync
    k = state["cur"].item()                          # host-sync
    mask = jnp.asarray([1, 0, 1, 0])                 # list-asarray
    return lens, k, mask


def allowed_body(state):
    k = state["cur"].item()  # staticcheck: ok[host-sync]
    return k


def drain(cache):  # staticcheck: host-boundary
    return jax.device_get(cache["lengths"])


def _cache_update(cache, x):
    return {**cache, "x": x}


undonated = jax.jit(_cache_update)                   # undonated-jit
donated = jax.jit(_cache_update, donate_argnums=(0,))


@partial(jax.jit)
def decorated_update(cache, x):                      # undonated-jit
    return {**cache, "x": x}


@partial(jax.jit, donate_argnums=(0,))
def decorated_ok(cache, x):
    return {**cache, "x": x}
