"""Data pipeline: determinism, style structure, eval scores sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (LanguageSpec, bigram_logits, sample_batch,
                        style_permutation, train_batch)

SPEC = LanguageSpec(vocab=128, seed=7, hard_style=True)


def test_stream_deterministic():
    b1 = train_batch(SPEC, seed=3, step=11, batch=4, seq=32)
    b2 = train_batch(SPEC, seed=3, step=11, batch=4, seq=32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = train_batch(SPEC, seed=3, step=12, batch=4, seq=32)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_shifted():
    b = train_batch(SPEC, seed=0, step=0, batch=2, seq=16)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # labels are the next tokens of the same sampled sequence
    full = sample_batch(jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(0), 0), 0), SPEC, 2, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                  np.asarray(full[:, :-1]))
    np.testing.assert_array_equal(np.asarray(b["labels"]),
                                  np.asarray(full[:, 1:]))


def test_style_markers_at_period():
    toks = np.asarray(sample_batch(jax.random.PRNGKey(0), SPEC, 4, 64,
                                   style=True))
    marker = SPEC.style_marker
    period = SPEC.style_period
    idx = np.arange(64)
    marker_pos = idx[idx % period == period - 1]
    assert (toks[:, marker_pos] == marker).all()
    non_marker = idx[(idx % period != period - 1)]
    assert (toks[:, non_marker[1:]] != marker).all()


def test_base_corpus_has_no_markers():
    toks = np.asarray(sample_batch(jax.random.PRNGKey(1), SPEC, 4, 64,
                                   style=False))
    # base bigram never emits the reserved marker (first token can't be it
    # either: randint upper bound excludes vocab-1)
    assert (toks != SPEC.style_marker).all()


def test_bigram_branching():
    logits = np.asarray(bigram_logits(SPEC))
    live = (logits > -20).sum(axis=1)
    assert (live == SPEC.branching).all()


def test_style_permutation_is_permutation():
    p = np.asarray(style_permutation(SPEC))
    assert sorted(p.tolist()) == list(range(SPEC.vocab))


def test_oracle_scores_bracket_model_scores():
    """A table-oracle 'model' scores ~2.0; random params score ~0."""
    from repro.data.synthetic import eval_scores
    from repro.configs import get_arch, reduced
    from repro.models import build_model
    cfg = reduced(get_arch("glm4-9b"))
    spec = LanguageSpec(vocab=cfg.vocab_size, seed=7, hard_style=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s = eval_scores(model, params, spec, batch=4, seq=64)
    assert 0.0 <= s["style"] <= 2.0 and 0.0 <= s["general"] <= 2.0
    assert s["style"] < 0.5  # untrained: no style
