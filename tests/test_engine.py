"""Serving-engine tests: greedy parity with the old host loop, slot reuse,
sampler behavior, sharded smoke, quantized embedding gather."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import (Engine, SamplingParams, sample, serve_host_loop)
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _setup(arch="glm4-9b", **repl):
    cfg = reduced(get_arch(arch))
    if repl:
        cfg = dataclasses.replace(cfg, **repl)
    model = build_model(cfg)
    params = model.init(KEY)
    spec = LanguageSpec(vocab=cfg.vocab_size)
    return cfg, model, params, spec


def _prompts(spec, lens):
    return [sample_batch(jax.random.PRNGKey(i), spec, 1, L)[0]
            for i, L in enumerate(lens)]


def test_engine_greedy_token_exact_vs_host_loop():
    """Device-resident K-step decode == old per-token host loop, token for
    token, including slot refills mid-stream."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [10, 10, 10, 10, 10])
    legacy = serve_host_loop(model, params, prompts, batch=2, gen_tokens=6,
                             cache_len=30)
    eng = Engine(model, params, slots=2, cache_len=30, k_steps=3)
    outs, stats = eng.serve(prompts, gen_tokens=6, return_stats=True)
    assert outs == legacy
    # at most one host sync per K decode steps (plus one per prefill group)
    assert stats["dispatches"] * eng.cfg.k_steps == stats["decode_steps"]
    assert stats["host_syncs"] == stats["dispatches"] + stats["prefill_calls"]


def test_engine_greedy_parity_unequal_lengths_padded_prefill():
    """The single right-padded multi-slot prefill call stays token-exact
    against the legacy batch-1-per-slot prefill."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [8, 13, 8, 11])
    eng = Engine(model, params, slots=3, cache_len=36, k_steps=2)
    assert eng._can_pad  # dense causal stack -> padded path is in play
    legacy = serve_host_loop(model, params, prompts, batch=3, gen_tokens=5,
                             cache_len=36)
    assert eng.serve(prompts, gen_tokens=5) == legacy


def test_engine_bucketed_prefill_for_ring_ssm_and_moe():
    """SWA-ring, Mamba-state and capacity-routed MoE configs refuse padding
    (pad tokens would corrupt ring slots / SSM state / expert capacity) and
    group prompts by exact length — outputs still match the legacy loop."""
    for arch, repl in (("mixtral-8x22b", {"capacity_factor": 8.0}),
                       ("mamba2-780m", {}),
                       ("deepseek-v3", {})):   # moe, no sliding window
        cfg, model, params, spec = _setup(arch, **repl)
        prompts = _prompts(spec, [9, 12, 9])
        eng = Engine(model, params, slots=2, cache_len=34, k_steps=2)
        assert not eng._can_pad
        legacy = serve_host_loop(model, params, prompts, batch=2,
                                 gen_tokens=4, cache_len=34)
        assert eng.serve(prompts, gen_tokens=4) == legacy


def test_engine_slot_reuse_more_requests_than_slots():
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [8] * 7)
    eng = Engine(model, params, slots=2, cache_len=24, k_steps=4)
    outs, stats = eng.serve(prompts, gen_tokens=5, return_stats=True)
    assert len(outs) == 7
    assert all(len(o) == 5 for o in outs)
    # 7 requests through 2 slots forces at least ceil(7/2) admission rounds
    assert stats["prefill_calls"] >= 4
    assert stats["tokens"] == 35


def test_sampler_modes_and_determinism():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (4, 32))
    # greedy == argmax regardless of key
    g = sample(logits, key, SamplingParams())
    assert jnp.array_equal(g, jnp.argmax(logits, -1).astype(jnp.int32))
    # top_k=1 collapses the categorical onto the argmax
    t1 = sample(logits, key, SamplingParams(greedy=False, temperature=0.7,
                                            top_k=1))
    assert jnp.array_equal(t1, g)
    # top-k draws never leave the per-row top-k set
    sp = SamplingParams(greedy=False, temperature=1.5, top_k=5)
    topk = jax.lax.top_k(logits, 5)[1]
    draws = jax.vmap(lambda k: sample(logits, k, sp))(
        jax.random.split(key, 32))
    assert bool(jnp.all((draws[..., None] == topk[None]).any(-1)))
    # fixed key -> deterministic; different key -> a different draw exists
    a = sample(logits, key, sp)
    assert jnp.array_equal(a, sample(logits, key, sp))
    with pytest.raises(ValueError):
        SamplingParams(greedy=False, temperature=0.0)


def test_engine_sampling_deterministic_under_fixed_seed():
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [9, 9, 9])
    sp = SamplingParams(greedy=False, temperature=0.8, top_k=8)
    eng = Engine(model, params, slots=2, cache_len=26, k_steps=3, sampling=sp)
    a = eng.serve(prompts, gen_tokens=6, seed=7)
    assert a == eng.serve(prompts, gen_tokens=6, seed=7)
    assert all(len(o) == 6 for o in a)


def test_engine_sharded_smoke_host_mesh():
    """Sharded serving on a host mesh reproduces unsharded outputs, and
    quantized storage/scale leaves inherit the dense weight's layout."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import params_shardings
    from repro.quantize import quantize

    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [10, 10, 10])
    mesh = make_host_mesh()
    ref = Engine(model, params, slots=2, cache_len=26,
                 k_steps=2).serve(prompts, gen_tokens=4)
    eng = Engine(model, params, slots=2, cache_len=26, k_steps=2, mesh=mesh)
    assert eng.serve(prompts, gen_tokens=4) == ref

    # quantized tree: .../wq/data and .../wq/scale follow the dense spec
    base = jax.tree.map(lambda p: p * 0.99 if p.ndim >= 2 else p, params)
    qparams, _ = quantize(params, base,
                          QuantConfig(method="absmax", granularity="channel"),
                          mode="storage", out_dtype="bfloat16")
    dense_sh = params_shardings(jax.eval_shape(lambda: params), cfg, mesh)
    quant_sh = params_shardings(jax.eval_shape(lambda: qparams), cfg, mesh)
    d = dense_sh["stack"]["L0"]["attn"]["wq"].spec
    q = quant_sh["stack"]["L0"]["attn"]["wq"].data.spec
    assert tuple(q) == tuple(d)
    # quantized params also serve sharded
    qeng = Engine(model, qparams, slots=2, cache_len=26, k_steps=2, mesh=mesh)
    qref = Engine(model, qparams, slots=2, cache_len=26,
                  k_steps=2).serve(prompts, gen_tokens=4)
    assert qeng.serve(prompts, gen_tokens=4) == qref


def test_qlinear_take_gathers_rows_before_dequant():
    """take() on a quantized table matches dequantize()[ids] for every
    granularity, with and without an equalization vector."""
    from repro.core.formats import get_format
    from repro.core.granularity import absmax_scale, quantize_store
    from repro.quant_runtime import qlinear
    from repro.quant_runtime.qparams import QuantizedTensor

    fmt = get_format("fp8_e4m3")
    table = jax.random.normal(KEY, (40, 24), jnp.float32)
    ids = jnp.asarray([[0, 5, 39], [17, 5, 2]], jnp.int32)
    for gran, bs in (("tensor", 128), ("channel", 128), ("block", 16)):
        scale = absmax_scale(table, gran, fmt, bs)
        q = quantize_store(table, scale, gran, fmt, bs)
        for eq in (None, jnp.abs(jax.random.normal(
                jax.random.PRNGKey(1), (40,))) + 0.5):
            qt = QuantizedTensor(q, scale, fmt="fp8_e4m3", granularity=gran,
                                 block_size=bs, out_dtype="bfloat16",
                                 eq_scale=eq)
            got = qlinear.take(qt, ids)
            want = qt.dequantize()[ids]
            assert got.shape == want.shape == (2, 3, 24)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=0, atol=0, err_msg=f"{gran} eq={eq is not None}")


def test_make_serve_step_deprecation_shim():
    cfg, model, params, spec = _setup()
    with pytest.warns(DeprecationWarning):
        from repro.launch.steps import make_serve_step
        step = make_serve_step(model)
    cache = model.init_cache(2, 16)
    toks = jnp.ones((2, 1), jnp.int32)
    nxt, logits, new_cache = jax.jit(step)(params, toks, cache)
    assert nxt.shape == (2, 1)
    assert jnp.array_equal(nxt[:, 0], jnp.argmax(logits, -1))
