"""Paged KV-cache tests: greedy token-exactness against the contiguous
engine AND the legacy host loop under adversarial workloads (mixed prompt
lengths, interleaved arrivals, slot churn, tight pools), allocator unit
invariants, and config validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import (Engine, blocks_for, init_block_state,
                          release_slots, serve_host_loop)
from repro.engine.paged import NEG, alloc_admit, alloc_step, gather_blocks
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

_BUILT: dict = {}


def _setup(arch="glm4-9b"):
    """Model + params, cached per arch so the jit caches stay warm across
    the randomized examples."""
    if arch not in _BUILT:
        cfg = reduced(get_arch(arch))
        model = build_model(cfg)
        params = model.init(KEY)
        _BUILT[arch] = (cfg, model, params,
                        LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[arch]


def _prompts(spec, lens, seed=0):
    return [sample_batch(jax.random.PRNGKey(seed * 1000 + i), spec, 1, L)[0]
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# Token-exactness: paged == contiguous == legacy host loop
# ---------------------------------------------------------------------------

def test_paged_token_exact_dense_mixed_lengths():
    """Dense causal stack, wildly different prompt lengths, more requests
    than slots (continuous slot churn)."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [10, 25, 6, 17, 9, 12])
    legacy = serve_host_loop(model, params, prompts, batch=2, gen_tokens=6,
                             cache_len=40)
    contig = Engine(model, params, slots=2, cache_len=40,
                    k_steps=3).serve(prompts, gen_tokens=6)
    peng = Engine(model, params, slots=2, cache_len=40, k_steps=3,
                  paged=True, block_size=8)
    pout, stats = peng.serve(prompts, gen_tokens=6, return_stats=True)
    assert contig == legacy
    assert pout == contig
    # the paged pool at capacity parity is the same order of bytes as the
    # contiguous cache (block-rounding + one trash block of overhead)
    assert stats["cache_bytes"] > 0


def test_paged_token_exact_swa_ring():
    """SWA config: the paged cache pages the ring itself (window 16,
    blocks of 8) and must reproduce ring-cache decoding exactly, including
    prompts longer than the window."""
    cfg, model, params, spec = _setup("mixtral-8x22b")
    assert cfg.sliding_window == 16
    prompts = _prompts(spec, [9, 21, 9, 14])
    legacy = serve_host_loop(model, params, prompts, batch=2, gen_tokens=5,
                             cache_len=34)
    peng = Engine(model, params, slots=2, cache_len=34, k_steps=2,
                  paged=True, block_size=8)
    assert peng.serve(prompts, gen_tokens=5) == legacy


def test_paged_routes_around_contiguous_state():
    """Mamba (pure SSM) and hybrid (Jamba) stacks: SSM state has no
    sequence axis to page and stays per-slot dense; outputs still match."""
    for arch in ("mamba2-780m", "jamba-v0.1-52b"):
        cfg, model, params, spec = _setup(arch)
        prompts = _prompts(spec, [9, 12, 9])
        contig = Engine(model, params, slots=2, cache_len=34,
                        k_steps=2).serve(prompts, gen_tokens=4)
        pout = Engine(model, params, slots=2, cache_len=34, k_steps=2,
                      paged=True, block_size=8).serve(prompts, gen_tokens=4)
        assert pout == contig, arch


def test_paged_tight_pool_serializes_but_stays_exact():
    """A pool too small for two concurrent requests forces sequential
    admission; outputs stay token-exact and every request completes."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [20, 20, 20, 20])
    contig = Engine(model, params, slots=2, cache_len=32,
                    k_steps=2).serve(prompts, gen_tokens=5)
    tight = Engine(model, params, slots=2, cache_len=32, k_steps=2,
                   paged=True, block_size=8, num_blocks=4)
    assert blocks_for(20 + 5 - 1, 8) == 3        # 3 of 4 blocks per request
    outs, stats = tight.serve(prompts, gen_tokens=5, return_stats=True)
    assert outs == contig
    # one admission round per request: the pool can never hold two
    assert stats["prefill_calls"] == 4


def test_paged_overlong_prompt_does_not_leak_blocks():
    """A prompt longer than the per-slot capacity only keeps its first
    ``cache_len`` rows (the contiguous cache drops the overflow the same
    way); the allocator must debit exactly the blocks the scatter places —
    an unclamped count would leak pool blocks and later hand out
    duplicates.  Serving many such prompts through a capacity-parity pool
    still terminates with every request answered."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [24, 24, 24, 24])       # cap is 16 rows
    contig = Engine(model, params, slots=2, cache_len=16,
                    k_steps=2).serve(prompts, gen_tokens=3)
    pout = Engine(model, params, slots=2, cache_len=16, k_steps=2,
                  paged=True, block_size=8).serve(prompts, gen_tokens=3)
    assert [len(o) for o in pout] == [3] * 4
    assert pout == contig


def test_paged_gen_tokens_one_releases_blocks_at_admission():
    """gen_tokens=1 finishes a slot inside the admission scatter; its
    blocks must come back so follow-up requests are not starved."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [16] * 6)
    contig = Engine(model, params, slots=2, cache_len=24,
                    k_steps=2).serve(prompts, gen_tokens=1)
    tight = Engine(model, params, slots=2, cache_len=24, k_steps=2,
                   paged=True, block_size=8, num_blocks=4)
    assert tight.serve(prompts, gen_tokens=1) == contig


# ---------------------------------------------------------------------------
# Chunked prefill: bit-identical to one-shot prefill across the families
# ---------------------------------------------------------------------------

def _setup_dropless(arch):
    """MoE configs at dropless capacity (capacity_factor = n_experts, so no
    token can overflow an expert queue).  Chunked prefill routes each chunk
    at full capacity by construction — GShard *round-major* capacity
    positions are non-causal (a token's 2nd-choice queue position depends
    on LATER tokens' 1st choices), so one-shot drop decisions are
    fundamentally unreproducible from a chunk's worth of tokens.  Exactness
    is therefore defined (and asserted) on dropless routing, which is what
    a serving engine wants regardless; weights are unaffected."""
    import dataclasses
    key = ("dropless", arch)
    if key not in _BUILT:
        cfg = reduced(get_arch(arch))
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
        model = build_model(cfg)
        params = model.init(KEY)
        _BUILT[key] = (cfg, model, params,
                       LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[key]


def test_chunked_prefill_token_exact_matrix():
    """Chunked prefill (prompts streaming through the decode dispatch in
    chunk_size pieces) must be token-exact vs the one-shot-prefill
    contiguous engine on every family: dense, SWA-ring (chunks wrap the
    paged ring), capacity-routed MoE (dropless — see _setup_dropless),
    pure SSM and hybrid (state threaded chunk-to-chunk on the SSD grid).
    Prompts cross chunk AND block boundaries and mix with in-flight
    decode (slot churn: more requests than slots).

    Each case runs the paged+prefix engine TWICE: the cold pass pins
    chunked-vs-one-shot (and in-run sharing where content-sound), the warm
    pass pins prefix-hit-vs-cold-cache — bit-identical outputs in every
    direction.  SWA/SSM/hybrid run with matching disabled (position-keyed
    rings / recurrent state can't be shared), so their warm pass pins that
    the persistent cache stays exact with sharing inert."""
    cases = [
        ("glm4-9b", False, 8, [10, 25, 6, 17], 40),      # dense
        ("mixtral-8x22b", True, 8, [9, 21, 9, 14], 34),  # SWA ring + MoE
        ("deepseek-v3", True, 8, [9, 21, 14], 34),       # MoE (no window)
        ("mamba2-780m", False, 32, [9, 40, 12], 48),     # pure SSM
        ("jamba-v0.1-52b", True, 32, [9, 40, 12], 48),   # hybrid
    ]
    for arch, moe, chunk, lens, cache_len in cases:
        cfg, model, params, spec = (_setup_dropless(arch) if moe
                                    else _setup(arch))
        prompts = _prompts(spec, lens)
        contig = Engine(model, params, slots=2, cache_len=cache_len,
                        k_steps=2).serve(prompts, gen_tokens=4)
        peng = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=2, paged=True, block_size=8, chunk_size=chunk,
                      prefix_cache=True, check_invariants=True)
        assert peng.serve(prompts, gen_tokens=4) == contig, arch   # cold
        assert peng.serve(prompts, gen_tokens=4) == contig, arch   # warm


def test_chunked_prefill_without_prefix_cache_exact():
    """Plain chunked prefill (no sharing, cow=False dispatch) on dense."""
    cfg, model, params, spec = _setup()
    prompts = _prompts(spec, [10, 25, 6, 17])
    contig = Engine(model, params, slots=2, cache_len=40,
                    k_steps=2).serve(prompts, gen_tokens=4)
    cout = Engine(model, params, slots=2, cache_len=40, k_steps=2,
                  paged=True, block_size=8, chunk_size=8,
                  check_invariants=True).serve(prompts, gen_tokens=4)
    assert cout == contig


def test_chunked_validation_errors():
    cfg, model, params, spec = _setup()
    with pytest.raises(ValueError, match="need paged"):
        Engine(model, params, slots=2, cache_len=32, chunk_size=8)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        Engine(model, params, slots=2, cache_len=16, paged=True,
               block_size=8, chunk_size=8).serve(
                   _prompts(spec, [24]), gen_tokens=2)
    cfg, model, params, spec = _setup("jamba-v0.1-52b")
    with pytest.raises(ValueError, match="multiple of ssm_chunk"):
        Engine(model, params, slots=2, cache_len=64, paged=True,
               block_size=8, chunk_size=8)


# ---------------------------------------------------------------------------
# Randomized stress: hypothesis-seeded mixed lengths / arrivals / churn
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_paged_stress_randomized(seed):
    """Adversarial workload sweep: random prompt lengths (some crossing
    block boundaries, some below one block), random request count vs slot
    count (interleaved arrivals + slot churn), random k_steps/gen and a
    randomly tightened pool.  Paged output must be token-exact against
    BOTH the contiguous engine and the legacy host loop."""
    rng = np.random.RandomState(seed)
    cfg, model, params, spec = _setup()
    slots = int(rng.randint(2, 4))
    n_req = int(rng.randint(slots, slots + 4))
    lens = [int(rng.randint(4, 29)) for _ in range(n_req)]
    gen = int(rng.randint(2, 7))
    k_steps = int(rng.randint(1, 4))
    cache_len = max(lens) + gen + int(rng.randint(0, 6))
    prompts = _prompts(spec, lens, seed=seed % 997)

    legacy = serve_host_loop(model, params, prompts, batch=slots,
                             gen_tokens=gen, cache_len=cache_len)
    contig = Engine(model, params, slots=slots, cache_len=cache_len,
                    k_steps=k_steps).serve(prompts, gen_tokens=gen)
    mb = blocks_for(cache_len, 8)
    full = slots * mb
    lo = max(blocks_for(L + gen - 1, 8) for L in lens)
    num_blocks = int(rng.randint(lo, full + 1))  # sometimes starved pool
    pout = Engine(model, params, slots=slots, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=8,
                  num_blocks=num_blocks).serve(prompts, gen_tokens=gen)
    assert contig == legacy
    assert pout == contig


# ---------------------------------------------------------------------------
# Allocator unit invariants
# ---------------------------------------------------------------------------

def test_block_allocator_invariants():
    """alloc/release round-trips conserve the pool: the free stack plus the
    union of table entries is always a partition of the block ids."""
    B, MB, NB = 3, 4, 8
    bstate = init_block_state(B, MB, NB)
    lengths = jnp.asarray([0, 5, 16], jnp.int32)
    bstate["slot_active"] = jnp.asarray([True, True, True])

    def held(bs):
        t = np.asarray(bs["tbl"])
        return set(t[t >= 0].tolist())

    def free_set(bs):
        f = np.asarray(bs["free"])
        return set(f[: int(bs["n_free"])].tolist())

    # decode-time allocation: slot 0 -> block j=0, slot 1 -> j=1, slot 2 -> j=4>=MB? no: 16//8=2
    bstate, wblk, woff, _ = alloc_step(bstate, lengths, 8, MB * 8, False)
    assert int(bstate["n_free"]) == NB - 3
    assert held(bstate) & free_set(bstate) == set()
    assert held(bstate) | free_set(bstate) == set(range(NB))
    # every allocated block carries exactly one reference
    assert all(int(bstate["ref"][b]) == 1 for b in held(bstate))
    # write targets point at the allocated blocks, offsets are in-block
    assert np.all(np.asarray(wblk) < NB)
    np.testing.assert_array_equal(np.asarray(woff), [0, 5, 0])

    # inactive slots route to the trash block and never allocate
    bstate["slot_active"] = jnp.asarray([True, False, True])
    b2, wblk2, _, _ = alloc_step(bstate, lengths + 1, 8, MB * 8, False)
    assert int(b2["n_free"]) == int(bstate["n_free"])
    assert int(wblk2[1]) == NB                    # trash index

    # release returns every held block exactly once
    b3 = release_slots(b2, jnp.asarray([True, True, True]))
    assert int(b3["n_free"]) == NB
    assert free_set(b3) == set(range(NB))
    assert np.all(np.asarray(b3["tbl"]) == NEG)
    assert not np.any(np.asarray(b3["slot_active"]))


def test_alloc_admit_counts_and_trash_padding():
    B, MB, NB = 4, 6, 12
    bstate = init_block_state(B, MB, NB)
    slots = jnp.asarray([1, 3], jnp.int32)
    counts = jnp.asarray([2, 5], jnp.int32)
    bstate, wids = alloc_admit(bstate, slots, counts, nbl=5)
    assert wids.shape == (2, 5)
    w = np.asarray(wids)
    assert np.all(w[0, 2:] == NB)                 # padded with trash
    assert np.all(w[1] < NB)
    ids = np.concatenate([w[0, :2], w[1]])
    assert len(set(ids.tolist())) == 7            # all distinct
    assert int(bstate["n_free"]) == NB - 7
    tbl = np.asarray(bstate["tbl"])
    assert np.all(tbl[0] == NEG) and np.all(tbl[2] == NEG)
    assert set(tbl[1][tbl[1] >= 0].tolist()) == set(w[0, :2].tolist())


def test_gather_blocks_reproduces_linear_layout():
    NB, bs, Kv, hd = 5, 4, 2, 3
    pool = jnp.arange((NB + 1) * bs * Kv * hd, dtype=jnp.float32).reshape(
        NB + 1, bs, Kv, hd)
    tbl = jnp.asarray([[2, 0, NEG], [4, NEG, NEG]], jnp.int32)
    g = gather_blocks(pool, tbl)
    assert g.shape == (2, 3 * bs, Kv, hd)
    np.testing.assert_array_equal(np.asarray(g[0, :bs]), np.asarray(pool[2]))
    np.testing.assert_array_equal(np.asarray(g[0, bs:2 * bs]),
                                  np.asarray(pool[0]))
    # NEG wraps to the trash block (index NB) — masked by callers
    np.testing.assert_array_equal(np.asarray(g[1, bs:2 * bs]),
                                  np.asarray(pool[NB]))


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_paged_validation_errors():
    cfg, model, params, spec = _setup("mixtral-8x22b")
    with pytest.raises(ValueError, match="cache_len >= sliding_window"):
        Engine(model, params, slots=2, cache_len=8, k_steps=2, paged=True,
               block_size=8).serve(_prompts(spec, [4]), gen_tokens=2)
    with pytest.raises(ValueError, match="must divide the sliding window"):
        Engine(model, params, slots=2, cache_len=34, k_steps=2, paged=True,
               block_size=6).serve(_prompts(spec, [4]), gen_tokens=2)

    cfg, model, params, spec = _setup()
    eng = Engine(model, params, slots=2, cache_len=64, k_steps=2,
                 paged=True, block_size=8, num_blocks=2)
    with pytest.raises(ValueError, match="blocks but the pool"):
        eng.serve(_prompts(spec, [40]), gen_tokens=4)
