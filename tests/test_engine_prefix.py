"""Prefix-cache + chunked-prefill tests: refcounted-allocator invariants
under adversarial workloads, copy-on-write unit semantics, LRU eviction
instead of admission deadlock, and warm-vs-cold token exactness.

The allocator invariants (asserted by ``Engine(check_invariants=True)``
after *every* admission and dispatch, on the device truth):

* no block leaked, no double-free: the free stack and the referenced
  blocks partition the pool (``n_free + |{ref > 0}| == num_blocks``);
* every block's refcount equals its live table references plus the host
  index/pending hold — so a dangling reference, a missed decrement or a
  double release trips immediately;
* no slot ever writes a block with ``refcount > 1``: prefill-chunk writes
  below the prefix-hit watermark are dropped (``span_targets``) and decode
  writes into shared blocks pop a private copy first (``alloc_step`` CoW)
  — pinned here both as unit tests and as warm-output bit-exactness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import (Engine, PrefixIndex, admit_slot, alloc_step,
                          blocks_for, chain_hashes, init_block_state,
                          release_refs, release_slots, span_targets)
from repro.engine.paged import NEG
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

_BUILT: dict = {}


def _setup(arch="glm4-9b"):
    if arch not in _BUILT:
        cfg = reduced(get_arch(arch))
        model = build_model(cfg)
        params = model.init(KEY)
        _BUILT[arch] = (cfg, model, params,
                        LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[arch]


def _tokens(spec, L, seed=0):
    return sample_batch(jax.random.PRNGKey(seed), spec, 1, L)[0]


# ---------------------------------------------------------------------------
# Allocator unit invariants: refcounts, sharing, CoW, host holds
# ---------------------------------------------------------------------------

def _conserved(bs, NB):
    n_free = int(bs["n_free"])
    free = [int(b) for b in np.asarray(bs["free"])[:n_free]]
    ref = np.asarray(bs["ref"])
    held = {b for b in range(NB) if ref[b] > 0}
    assert len(set(free)) == n_free
    assert not (set(free) & held)
    assert n_free + len(held) == NB
    return ref


def test_admit_slot_shared_and_retained_refs():
    """Prefix-hit admission: shared blocks gain a reference without
    consuming pool capacity; popped blocks start at ref 1, pre-retained
    (to-be-registered) ones at ref 2."""
    B, MB, NB = 2, 4, 10
    bs = init_block_state(B, MB, NB)
    # slot 0 allocates 3 blocks the classic way (simulating a past prompt)
    bs, ids0 = admit_slot(bs, jnp.int32(0), jnp.full((MB,), NEG, jnp.int32),
                          jnp.int32(0), jnp.int32(3), jnp.int32(2), MB)
    ids0 = [int(i) for i in np.asarray(ids0)[:3]]
    ref = _conserved(bs, NB)
    assert [ref[b] for b in ids0] == [2, 2, 1]    # 2 retained + 1 private
    # slot 1 admits sharing slot 0's two retained blocks + 1 fresh block
    shared = np.full((MB,), NEG, np.int32)
    shared[:2] = ids0[:2]
    bs, ids1 = admit_slot(bs, jnp.int32(1), jnp.asarray(shared),
                          jnp.int32(2), jnp.int32(1), jnp.int32(0), MB)
    ref = _conserved(bs, NB)
    assert [ref[b] for b in ids0[:2]] == [3, 3]   # +1 table ref each
    assert int(bs["n_free"]) == NB - 4            # sharing costs nothing
    tbl = np.asarray(bs["tbl"])
    assert tbl[1, 0] == ids0[0] and tbl[1, 1] == ids0[1]

    # releasing slot 1 only decrements; the shared blocks survive
    bs2 = release_slots(bs, jnp.asarray([False, True]))
    ref = _conserved(bs2, NB)
    assert [ref[b] for b in ids0[:2]] == [2, 2]
    # releasing slot 0 leaves the index hold (ref 1) on retained blocks;
    # the private block frees
    bs3 = release_slots(bs2, jnp.asarray([True, False]))
    ref = _conserved(bs3, NB)
    assert [ref[b] for b in ids0] == [1, 1, 0]
    # evicting the index holds frees everything — and is NOT idempotent
    # abuse-proof by design: each call drops one hold
    bs4 = release_refs(bs3, jnp.asarray(ids0[:2], jnp.int32))
    ref = _conserved(bs4, NB)
    assert int(bs4["n_free"]) == NB
    assert not np.any(ref)


def test_alloc_step_cow_pops_private_copy():
    """A decode write landing in a shared block (ref > 1) must rewire the
    slot to a fresh block and report the source for the row copy."""
    B, MB, NB = 2, 3, 6
    bs = init_block_state(B, MB, NB)
    # both slots share block table entry 0 -> block id via admit
    bs, ids = admit_slot(bs, jnp.int32(0), jnp.full((MB,), NEG, jnp.int32),
                         jnp.int32(0), jnp.int32(1), jnp.int32(1), MB)
    b0 = int(np.asarray(ids)[0])
    shared = np.full((MB,), NEG, np.int32)
    shared[0] = b0
    bs, _ = admit_slot(bs, jnp.int32(1), jnp.asarray(shared), jnp.int32(1),
                       jnp.int32(0), jnp.int32(0), MB)
    bs["slot_active"] = jnp.asarray([True, True])
    assert int(bs["ref"][b0]) == 3                # 2 tables + 1 hold
    # slot 1 writes at position 4 (inside the shared block, block_size 8)
    lengths = jnp.asarray([0, 4], jnp.int32)
    bs["slot_active"] = jnp.asarray([False, True])
    b2, wblk, woff, cow_src = alloc_step(bs, lengths, 8, MB * 8, False,
                                         cow=True)
    ref = _conserved(b2, NB)
    w1 = int(wblk[1])
    assert w1 != b0 and w1 < NB                   # private copy popped
    assert int(cow_src[1]) == b0                  # copy source reported
    assert int(woff[1]) == 4
    assert ref[b0] == 2                           # slot 1's ref moved off
    assert ref[w1] == 1
    assert int(np.asarray(b2["tbl"])[1, 0]) == w1
    # without sharing, cow is the identity (cow_src == wblk)
    b3, wblk3, _, cow3 = alloc_step(b2, lengths + 1, 8, MB * 8, False,
                                    cow=True)
    assert int(cow3[1]) == int(wblk3[1])


def test_span_targets_drop_shared_watermark():
    """Prefill-chunk writes below the prefix-hit watermark are dropped
    (the cached rows already hold the identical KV): no slot ever writes a
    block another owner reads."""
    B, MB, NB = 1, 4, 8
    bs = init_block_state(B, MB, NB)
    shared = np.full((MB,), NEG, np.int32)
    bs, ids = admit_slot(bs, jnp.int32(0), jnp.asarray(shared), jnp.int32(0),
                         jnp.int32(3), jnp.int32(0), MB)
    wblk, woff = span_targets(bs, jnp.asarray([14], jnp.int32),
                              jnp.asarray([6], jnp.int32), 8, 8, MB * 8,
                              False, jnp.asarray([16], jnp.int32))
    w = np.asarray(wblk)[0]
    tbl = np.asarray(bs["tbl"])[0]
    assert np.all(w[:2] == NB)                    # rows 14,15 < watermark
    assert np.all(w[2:6] == tbl[2])               # rows 16..19 writable
    assert np.all(w[6:] == NB)                    # pads beyond valid
    np.testing.assert_array_equal(np.asarray(woff)[0, 2:6], [0, 1, 2, 3])


# ---------------------------------------------------------------------------
# PrefixIndex unit behavior
# ---------------------------------------------------------------------------

def test_prefix_index_match_register_evict():
    idx = PrefixIndex(block_size=4)
    toks = list(range(10))                        # 2 full blocks + tail 2
    assert chain_hashes(toks, 4) == chain_hashes(toks + [99], 4)
    assert idx.match(toks) == ([], None, [])
    dups = idx.register(toks, [7, 3], 0)
    assert dups == [] and len(idx) == 2
    full, partial, keys = idx.match(toks)
    assert full == [7, 3] and partial is None and len(keys) == 2
    # a shorter prompt with a partial tail matching block 1's first rows
    full, partial, _ = idx.match(toks[:6])
    assert full == [7] and partial == 3
    # diverging content stops the chain at the divergence
    full, partial, _ = idx.match([0, 1, 2, 3, 9, 9, 9, 9, 5])
    assert full == [7] and partial is None
    # duplicate registration keeps the original
    assert idx.register(toks, [11, 12], 0) == [11, 12]
    # pinned entries refuse eviction; parents outlive their children
    full, _, keys = idx.match(toks)
    idx.pin(keys)
    assert idx.evict(2) == []
    idx.unpin(keys)
    assert idx.evict(2) == [3, 7]                 # leaf first, then parent
    assert len(idx) == 0


# ---------------------------------------------------------------------------
# Eviction instead of FIFO-wait deadlock
# ---------------------------------------------------------------------------

def test_admission_evicts_cached_blocks_instead_of_deadlocking():
    """A pool whose capacity is entirely held by cached (table-unreferenced)
    prefix blocks must evict LRU entries at admission, not wait forever."""
    cfg, model, params, spec = _setup()
    common = _tokens(spec, 16, seed=5)
    a = jnp.concatenate([common, _tokens(spec, 6, seed=6)])
    b = jnp.concatenate([common, _tokens(spec, 6, seed=7)])
    c = _tokens(spec, 22, seed=8)                 # unrelated content
    contig = Engine(model, params, slots=1, cache_len=32,
                    k_steps=2).serve([a, b, c], gen_tokens=4)
    # pool of 5 blocks: one 22-token request demands 4 (2 cached-prefix
    # holds + tail block + decode growth); after a+b the index still holds
    # a's 2-block prefix, so admitting c (2 new holds + 2 slot blocks on
    # top of the 2 cached) exceeds the pool and must evict LRU entries
    eng = Engine(model, params, slots=1, cache_len=32, k_steps=2,
                 paged=True, block_size=8, num_blocks=5, prefix_cache=True,
                 chunk_size=8, check_invariants=True)
    outs, stats = eng.serve([a, b, c], gen_tokens=4, return_stats=True)
    assert outs == contig
    assert stats["prefix_evictions"] > 0
    assert stats["prefix_hits"] > 0               # b still hit a's prefix


def test_warm_partial_hit_on_saturated_pool_degrades_not_crashes():
    """A pool whose every block is cached AND matched by the incoming
    request: the request's own pins would make nothing evictable and the
    partial-hit CoW spare cannot be found — admission must unpin and
    force-evict its own matches (degrading toward a cold prefill) instead
    of stalling an idle pool."""
    cfg, model, params, spec = _setup()
    long = _tokens(spec, 24, seed=51)[:24]        # exactly 3 full blocks
    short = long[:20]                             # partial hit in block 2
    contig_l = Engine(model, params, slots=1, cache_len=24,
                      k_steps=2).serve([long], gen_tokens=8)
    contig_s = Engine(model, params, slots=1, cache_len=24,
                      k_steps=2).serve([short], gen_tokens=8)
    eng = Engine(model, params, slots=1, cache_len=24, k_steps=2,
                 paged=True, block_size=8, num_blocks=3, prefix_cache=True,
                 chunk_size=8, check_invariants=True)
    assert eng.serve([long], gen_tokens=8) == contig_l
    # all 3 pool blocks are now index-held; the partial hit would pin all
    # of them and still need a CoW spare — must evict its own LRU match
    outs, stats = eng.serve([short], gen_tokens=8, return_stats=True)
    assert outs == contig_s
    assert stats["prefix_evictions"] > 0


# ---------------------------------------------------------------------------
# Warm vs cold token exactness (incl. partial-hit CoW) + fewer prefills
# ---------------------------------------------------------------------------

def test_prefix_warm_hits_are_token_exact_and_cheaper():
    cfg, model, params, spec = _setup()
    sysp = _tokens(spec, 20, seed=11)             # 2.5 blocks of 8
    prompts = [jnp.concatenate([sysp, _tokens(spec, 6, seed=20 + i)])
               for i in range(3)]
    contig = Engine(model, params, slots=2, cache_len=48,
                    k_steps=3).serve(prompts, gen_tokens=6)

    eng = Engine(model, params, slots=2, cache_len=48, k_steps=3,
                 paged=True, block_size=8, num_blocks=24, prefix_cache=True,
                 chunk_size=8, check_invariants=True)
    cold, cs = eng.serve(prompts, gen_tokens=6, return_stats=True)
    warm, ws = eng.serve(prompts, gen_tokens=6, return_stats=True)
    assert cold == contig                         # in-run sharing is exact
    assert warm == contig                         # cross-run hits are exact
    assert ws["prefill_tokens"] < cs["prefill_tokens"]
    assert ws["prefix_hits"] > cs["prefix_hits"]


def test_partial_block_hit_copy_on_write_exact():
    """A prompt that is a mid-block prefix of a cached prompt maps the
    cached partial block shared; its first decode write must CoW a private
    copy — the cached request re-served afterwards still sees its own rows
    (bit-exact), proving the copy really copied."""
    cfg, model, params, spec = _setup()
    long = _tokens(spec, 24, seed=31)             # 3 full blocks
    short = long[:20]                             # partial hit in block 2
    contig_l = Engine(model, params, slots=1, cache_len=40,
                      k_steps=2).serve([long], gen_tokens=5)
    contig_s = Engine(model, params, slots=1, cache_len=40,
                      k_steps=2).serve([short], gen_tokens=5)
    eng = Engine(model, params, slots=1, cache_len=40, k_steps=2,
                 paged=True, block_size=8, num_blocks=12, prefix_cache=True,
                 chunk_size=8, check_invariants=True)
    assert eng.serve([long], gen_tokens=5) == contig_l
    assert eng.serve([short], gen_tokens=5) == contig_s   # CoW path
    assert eng._index.partial_hits > 0
    assert eng.serve([long], gen_tokens=5) == contig_l    # rows uncorrupted


def test_prefix_gen_tokens_one_releases_and_still_caches():
    """gen_tokens=1 drains the slot inside the very dispatch that finishes
    its prefill; the pre-retained prompt blocks must survive the in-scan
    release and serve the next request's hits."""
    cfg, model, params, spec = _setup()
    prompts = [_tokens(spec, 16, seed=41)] * 3
    contig = Engine(model, params, slots=2, cache_len=24,
                    k_steps=2).serve(prompts, gen_tokens=1)
    eng = Engine(model, params, slots=2, cache_len=24, k_steps=2,
                 paged=True, block_size=8, num_blocks=8, prefix_cache=True,
                 chunk_size=8, check_invariants=True)
    outs, stats = eng.serve(prompts, gen_tokens=1, return_stats=True)
    assert outs == contig
    assert stats["prefix_hits"] > 0


# ---------------------------------------------------------------------------
# Hypothesis-seeded stress sweep: prompt families, churn, tight pools
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_prefix_stress_randomized(seed):
    """Random prompt families (shared prefixes of random depth), more
    requests than slots (interleaved arrivals + slot churn), random
    chunk/k_steps/gen and a randomly tightened pool — with the allocator
    conservation invariants asserted after every admission and dispatch
    (check_invariants), outputs token-exact vs the contiguous engine, both
    cold and warm."""
    rng = np.random.RandomState(seed)
    cfg, model, params, spec = _setup()
    slots = int(rng.randint(2, 4))
    n_fam = int(rng.randint(1, 4))
    fams = [_tokens(spec, int(rng.randint(4, 22)), seed=seed % 911 + f)
            for f in range(n_fam)]
    n_req = int(rng.randint(slots, slots + 4))
    prompts, lens = [], []
    for i in range(n_req):
        fam = fams[int(rng.randint(n_fam))]
        depth = int(rng.randint(0, fam.shape[0] + 1))
        tail = _tokens(spec, int(rng.randint(1, 9)), seed=seed % 877 + 50 + i)
        p = jnp.concatenate([fam[:depth], tail])
        prompts.append(p)
        lens.append(int(p.shape[0]))
    gen = int(rng.randint(1, 7))
    k_steps = int(rng.randint(1, 4))
    chunk = int(rng.choice([4, 8, 16]))
    cache_len = max(lens) + gen + int(rng.randint(0, 6))
    contig = Engine(model, params, slots=slots, cache_len=cache_len,
                    k_steps=k_steps).serve(prompts, gen_tokens=gen)
    lo = max(blocks_for(min(L + gen - 1, cache_len), 8) + 1 for L in lens)
    full = slots * blocks_for(cache_len, 8) + 4
    num_blocks = int(rng.randint(lo, full + 1))   # sometimes starved pool
    eng = Engine(model, params, slots=slots, cache_len=cache_len,
                 k_steps=k_steps, paged=True, block_size=8,
                 num_blocks=num_blocks, prefix_cache=True, chunk_size=chunk,
                 check_invariants=True)
    outs, stats = eng.serve(prompts, gen_tokens=gen, return_stats=True)
    assert outs == contig
    # device-counter conservation: after the drain the only blocks out of
    # the pool are the prefix index's holds ("popped == released + live")
    c = stats["counters"]
    assert (c["blocks_popped"] - c["blocks_released"]
            == len(eng._hold_blocks))
    assert c["prefix_hit_tokens"] == stats["prefix_hits"]
    assert c["tokens"] == stats["tokens"]
    held0 = len(eng._hold_blocks)
    outs, stats = eng.serve(prompts, gen_tokens=gen, return_stats=True)
    assert outs == contig                                 # warm pass
    c = stats["counters"]
    # warm counters re-zero and re-baseline on the blocks held at start
    assert (held0 + c["blocks_popped"] - c["blocks_released"]
            == len(eng._hold_blocks))
    assert c["prefix_hit_tokens"] == stats["prefix_hits"]
