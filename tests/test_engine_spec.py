"""Self-speculative decoding tests: greedy token-exactness against the
non-speculative paged engine across every family (any draft, good or
terrible), the composed-config matrix (speculation × prefix cache ×
chunked prefill, including warm partial hits that copy-on-write the
speculative span), rollback/allocator invariants under randomized stress,
the dynamic-depth controller (pinned trajectories + zero recompiles),
the acceptance rules as pure functions, sampler distribution correctness
(temperature / top-k / top-p frequency + lossless rejection-sampling
unbiasedness), config validation, and the quantized-head matmul."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import (DepthController, Engine, SamplingParams,
                          alloc_span, blocks_for, greedy_accept,
                          init_block_state, probs, rejection_accept, sample)
from repro.models import build_model
from repro.quantize import quantize

KEY = jax.random.PRNGKey(0)

_BUILT: dict = {}


def _setup(arch="glm4-9b", dropless=False):
    """Model + params (+ a quantized absmax draft tree and a wrong-seed
    'bad' draft), cached per arch so jit caches stay warm."""
    key = (arch, dropless)
    if key not in _BUILT:
        cfg = reduced(get_arch(arch))
        if dropless:
            cfg = dataclasses.replace(cfg,
                                      capacity_factor=float(cfg.n_experts))
        model = build_model(cfg)
        params = model.init(KEY)
        draft, _ = quantize(params, None,
                            QuantConfig(method="absmax",
                                        granularity="channel"),
                            mode="storage", out_dtype="bfloat16")
        bad = model.init(jax.random.PRNGKey(99))
        _BUILT[key] = (cfg, model, params, draft, bad,
                       LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[key]


def _prompts(spec, lens, seed=0):
    return [sample_batch(jax.random.PRNGKey(seed * 1000 + i), spec, 1, L)[0]
            for i, L in enumerate(lens)]


def _shared_prompts(spec, lens, share, seed=0, dup=True):
    """Prompts sharing a ``share``-token system prefix; with ``dup`` the
    first prompt is appended again verbatim, so serving it a second time
    lands a warm *partial* hit (the whole prompt, final part-block
    included, is already cached — the spot where a speculative span's
    first entry is a shared block and must copy-on-write)."""
    pre = sample_batch(jax.random.PRNGKey(7 + seed), spec, 1, share)[0][:share]
    tails = _prompts(spec, [L - share for L in lens], seed=seed)
    out = [jnp.concatenate([pre, t]) for t in tails]
    return out + [out[0]] if dup else out


# ---------------------------------------------------------------------------
# Greedy token-exactness: spec == non-spec paged engine, every family
# ---------------------------------------------------------------------------

def test_spec_token_exact_matrix():
    """Speculative greedy output must equal the non-speculative paged
    engine token for token on dense, SWA-ring+MoE, MoE, pure-SSM and
    hybrid configs (MoE at dropless capacity, as for chunked prefill: the
    verify chunk routes dropless by construction).  The draft is a real
    absmax-quantized tree, so rounds mix accepts and rejections; the
    acceptance rate must be meaningful (> 0) for a draft this close."""
    cases = [
        ("glm4-9b", False, [10, 25, 6, 17], 40),
        ("mixtral-8x22b", True, [9, 21, 9, 14], 34),   # SWA ring + MoE
        ("deepseek-v3", True, [9, 21, 14], 34),        # MoE, prefix stack
        ("mamba2-780m", False, [9, 40, 12], 48),       # pure SSM
        ("jamba-v0.1-52b", True, [9, 40, 12], 48),     # hybrid
    ]
    for arch, moe, lens, cache_len in cases:
        cfg, model, params, draft, _, spec = _setup(arch, dropless=moe)
        prompts = _prompts(spec, lens)
        base = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8
                      ).serve(prompts, gen_tokens=5)
        seng = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8, n_spec=2,
                      draft_params=draft, check_invariants=True)
        outs, stats = seng.serve(prompts, gen_tokens=5, return_stats=True)
        assert outs == base, arch
        assert stats["draft_tokens"] > 0
        assert 0 < stats["draft_accepted"] <= stats["draft_tokens"], arch


def test_spec_composed_token_exact_matrix():
    """The full composition — speculation × prefix cache × chunked
    prefill — must equal the *non-speculative* paged+prefix engine token
    for token on every family.  Prompts share a system prefix and one
    prompt repeats verbatim, so the dense/MoE runs land full-block hits,
    a warm partial hit (copy-on-write of the speculative span's first
    entry), and admissions that start chunking while resident slots are
    mid-speculation.  Ring (SWA) and recurrent (SSM/hybrid) families run
    the same composition unshared — exactness must hold with zero hits
    too."""
    cases = [
        # arch, moe, chunk, lens (> share), cache_len, hits expected
        ("glm4-9b", False, 8, [18, 25, 18, 21], 40, True),
        ("mixtral-8x22b", True, 8, [18, 21, 18], 34, False),   # SWA ring
        ("deepseek-v3", True, 8, [18, 21, 18], 34, True),      # MoE
        ("mamba2-780m", False, 32, [18, 40, 18], 48, False),   # pure SSM
        ("jamba-v0.1-52b", True, 32, [18, 40, 18], 48, False),  # hybrid
    ]
    for arch, moe, chunk, lens, cache_len, can_hit in cases:
        cfg, model, params, draft, _, spec = _setup(arch, dropless=moe)
        prompts = _shared_prompts(spec, lens, share=16)
        base = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8, chunk_size=chunk,
                      prefix_cache=True).serve(prompts, gen_tokens=5)
        seng = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8, chunk_size=chunk,
                      prefix_cache=True, n_spec=2, draft_params=draft,
                      check_invariants=True)
        outs, stats = seng.serve(prompts, gen_tokens=5, return_stats=True)
        assert outs == base, arch
        assert 0 < stats["draft_accepted"] <= stats["draft_tokens"], arch
        if can_hit:  # shared prefix + duplicated prompt must actually hit
            assert stats["prefix_hits"] > 0, arch
        else:        # ring / recurrent caches never share
            assert stats["prefix_hits"] == 0, arch


def test_spec_composed_warm_prefix_hit_mid_speculation():
    """Serving the same requests twice on one composed engine: the second
    pass is fully warm — every admission is a prefix hit landing while a
    resident slot is mid-speculation, and the duplicated prompt's partial
    hit forces the speculative span's first entry through copy-on-write.
    Both passes must match the non-speculative prefix engine served
    identically."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _shared_prompts(spec, [18, 25, 21], share=16)
    base = Engine(model, params, slots=2, cache_len=40, k_steps=3,
                  paged=True, block_size=8, chunk_size=8, prefix_cache=True)
    b1 = base.serve(prompts, gen_tokens=5)
    b2 = base.serve(prompts, gen_tokens=5)
    seng = Engine(model, params, slots=2, cache_len=40, k_steps=3,
                  paged=True, block_size=8, chunk_size=8, prefix_cache=True,
                  n_spec=2, draft_params=draft, check_invariants=True)
    o1, s1 = seng.serve(prompts, gen_tokens=5, return_stats=True)
    o2, s2 = seng.serve(prompts, gen_tokens=5, return_stats=True)
    assert o1 == b1 and o2 == b2
    assert s2["prefix_hits"] > s1["prefix_hits"]   # warm second pass
    assert s2["draft_accepted"] > 0


def test_spec_composed_exact_for_garbage_draft():
    """A wrong-seed draft (≈0% acceptance, a rollback every round) through
    the full composition: every rollback rolls a length back *into* CoW'd
    and freshly-popped span blocks, and the output must still equal the
    non-speculative prefix engine exactly."""
    cfg, model, params, _, bad, spec = _setup()
    prompts = _shared_prompts(spec, [18, 21, 18], share=16)
    base = Engine(model, params, slots=2, cache_len=40, k_steps=4,
                  paged=True, block_size=8, chunk_size=8, prefix_cache=True
                  ).serve(prompts, gen_tokens=6)
    outs, stats = Engine(model, params, slots=2, cache_len=40, k_steps=4,
                         paged=True, block_size=8, chunk_size=8,
                         prefix_cache=True, n_spec=2, draft_params=bad,
                         check_invariants=True
                         ).serve(prompts, gen_tokens=6, return_stats=True)
    assert outs == base
    assert stats["prefix_hits"] > 0
    assert stats["draft_accepted"] < stats["draft_tokens"] // 4


def test_spec_exact_for_any_draft_even_garbage():
    """The lossless guarantee is structural: a draft from a completely
    different seed (≈0% acceptance → a rollback every round) must still
    reproduce the non-speculative greedy output exactly — the draft only
    chooses how many verifier-identical tokens emit per round."""
    cfg, model, params, _, bad, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6, 9])
    base = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                  paged=True, block_size=8).serve(prompts, gen_tokens=6)
    outs, stats = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                         paged=True, block_size=8, n_spec=2,
                         draft_params=bad, check_invariants=True
                         ).serve(prompts, gen_tokens=6, return_stats=True)
    assert outs == base
    # wrong-seed drafts agree with the verifier about nothing
    assert stats["draft_accepted"] < stats["draft_tokens"] // 4


def test_spec_budget_clamp_edges():
    """A round can accept past the remaining budget; emission is clamped
    without changing values.  gen=1 never decodes, gen=2 clamps the very
    first round (n_spec=3 > remaining=1)."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6, 9])
    for gen in (1, 2, 4):
        base = Engine(model, params, slots=2, cache_len=32, k_steps=5,
                      paged=True, block_size=8).serve(prompts,
                                                      gen_tokens=gen)
        outs = Engine(model, params, slots=2, cache_len=32, k_steps=5,
                      paged=True, block_size=8, n_spec=3,
                      draft_params=draft, check_invariants=True
                      ).serve(prompts, gen_tokens=gen)
        assert outs == base, gen
        assert [len(o) for o in outs] == [gen] * len(prompts)


def test_spec_tight_pool_with_reservation_slack():
    """The reservation ledger counts the speculative span (up to n_spec
    rows past the budget) into each slot's worst case: a pool sized to
    exactly that bound serializes but stays exact and never starves."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [20, 20, 20, 20])
    base = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                  paged=True, block_size=8).serve(prompts, gen_tokens=5)
    need = blocks_for(20 + 5 - 1 + 2, 8)          # lifetime + n_spec slack
    tight = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                   paged=True, block_size=8, num_blocks=need, n_spec=2,
                   draft_params=draft, check_invariants=True)
    outs, stats = tight.serve(prompts, gen_tokens=5, return_stats=True)
    assert outs == base
    assert stats["prefill_calls"] == 4            # one slot at a time fits


def test_spec_sampled_mode_deterministic_and_complete():
    """Sampled speculative serving is not token-exact vs non-speculative
    sampling (different PRNG consumption) but must be deterministic under
    a fixed seed and deliver full budgets of in-vocab tokens."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6])
    sp = SamplingParams(greedy=False, temperature=0.9, top_k=40, top_p=0.9)
    eng = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                 paged=True, block_size=8, n_spec=2, draft_params=draft,
                 sampling=sp, check_invariants=True)
    o1 = eng.serve(prompts, gen_tokens=6, seed=7)
    o2 = eng.serve(prompts, gen_tokens=6, seed=7)
    assert o1 == o2
    assert [len(o) for o in o1] == [6, 6, 6]
    assert all(0 <= t < cfg.vocab_size for o in o1 for t in o)


# ---------------------------------------------------------------------------
# Randomized stress: mixed accept/reject rollbacks + allocator invariants
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_spec_stress_randomized(seed):
    """Adversarial sweep: random prompt lengths / request counts / budgets
    / draft depth / pool tightness, alternating a close (quantized) and a
    hostile (wrong-seed) draft — so rounds mix full accepts, partial
    rollbacks and full rejections while slots churn and blocks recycle.
    Output must equal the non-speculative paged engine token for token,
    with allocator conservation asserted after every dispatch
    (check_invariants)."""
    rng = np.random.RandomState(seed)
    cfg, model, params, draft, bad, spec = _setup()
    slots = 2
    n_req = int(rng.randint(slots, slots + 4))
    lens = [int(rng.randint(4, 29)) for _ in range(n_req)]
    gen = int(rng.randint(2, 7))
    k_steps = int(rng.randint(2, 4))
    n_spec = int(rng.randint(1, k_steps))          # < k_steps
    cache_len = max(lens) + gen + int(rng.randint(0, 6))
    dtree = draft if seed % 2 == 0 else bad
    prompts = _prompts(spec, lens, seed=seed % 997)

    base = Engine(model, params, slots=slots, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=8
                  ).serve(prompts, gen_tokens=gen)
    full = slots * blocks_for(cache_len, 8)
    lo = max(blocks_for(L + gen - 1 + n_spec, 8) for L in lens)
    num_blocks = int(rng.randint(lo, full + 1))    # sometimes starved pool
    outs, stats = Engine(model, params, slots=slots, cache_len=cache_len,
                         k_steps=k_steps, paged=True, block_size=8,
                         num_blocks=num_blocks, n_spec=n_spec,
                         draft_params=dtree, check_invariants=True
                         ).serve(prompts, gen_tokens=gen, return_stats=True)
    assert outs == base
    # device-counter conservation over the whole randomized run
    c = stats["counters"]
    assert c["drafted"] == c["accepted"] + c["rejected"]
    assert c["blocks_popped"] == c["blocks_released"]  # fully drained
    assert c["drafted"] == stats["draft_tokens"]
    assert c["accepted"] == stats["draft_accepted"]


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_spec_composed_stress_randomized(seed):
    """The composed sweep: random shared-prefix workloads (one prompt
    duplicated, so warm partial hits copy-on-write the speculative span)
    through speculation × prefix cache × chunked prefill, with the pool
    randomly squeezed down to *exactly* the reservation bound — lifetime
    blocks + n_spec slack + the one-CoW spare.  Refcount conservation
    (``n_free + |ref>0| == num_blocks``) is asserted after every dispatch
    (check_invariants), i.e. after every speculative rollback and CoW pop;
    output must equal the non-speculative prefix engine token for token."""
    rng = np.random.RandomState(seed)
    cfg, model, params, draft, bad, spec = _setup()
    slots = 2
    n_req = int(rng.randint(slots, slots + 3))
    share = 8 * int(rng.randint(0, 3))             # 0 / 8 / 16 shared rows
    lens = [int(rng.randint(share + 2, 29)) for _ in range(n_req)]
    gen = int(rng.randint(2, 7))
    k_steps = int(rng.randint(2, 4))
    n_spec = int(rng.randint(1, k_steps))          # < k_steps
    chunk = 8 * int(rng.randint(1, 3))
    cache_len = max(lens) + gen + int(rng.randint(0, 6))
    dtree = draft if seed % 2 == 0 else bad
    prompts = _shared_prompts(spec, lens, share, seed=seed % 997)

    base = Engine(model, params, slots=slots, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=8,
                  chunk_size=chunk, prefix_cache=True
                  ).serve(prompts, gen_tokens=gen)
    mb = blocks_for(cache_len, 8)
    lo = max(min(blocks_for(L + gen - 1 + n_spec, 8), mb)
             for L in lens) + 1                    # + the CoW spare
    num_blocks = int(rng.randint(lo, slots * mb + 1))
    eng = Engine(model, params, slots=slots, cache_len=cache_len,
                 k_steps=k_steps, paged=True, block_size=8,
                 chunk_size=chunk, prefix_cache=True,
                 num_blocks=num_blocks, n_spec=n_spec, draft_params=dtree,
                 check_invariants=True)
    outs, stats = eng.serve(prompts, gen_tokens=gen, return_stats=True)
    assert outs == base
    # device-counter conservation: drafts balance, and after the drain the
    # only blocks still out of the pool are the prefix index's holds
    c = stats["counters"]
    assert c["drafted"] == c["accepted"] + c["rejected"]
    assert (c["blocks_popped"] - c["blocks_released"]
            == len(eng._hold_blocks))
    assert c["prefix_hit_tokens"] == stats["prefix_hits"]


# ---------------------------------------------------------------------------
# Dynamic draft depth: controller trajectories + zero recompiles
# ---------------------------------------------------------------------------

def test_depth_controller_pinned_trajectories():
    """AIMD depth moves on synthetic acceptance traces, pinned exactly."""
    # sustained hits at the ceiling stay at the ceiling
    c = DepthController(4)
    assert c.depth == 4
    assert [c.update(8, 8) for _ in range(5)] == [4] * 5
    # sustained misses halve to 1 and stay: 4 -> 2 -> 1 -> 1
    c = DepthController(4)
    assert [c.update(8, 0) for _ in range(4)] == [2, 1, 1, 1]
    # climb from 1: one step up per `patience` consecutive hits
    c = DepthController(4, depth=1)
    assert [c.update(4, 4) for _ in range(8)] == [1, 2, 2, 3, 3, 4, 4, 4]
    # alternating hit/miss decays to 1 and is stable there
    c = DepthController(4)
    trace = [c.update(4, 4 if i % 2 == 0 else 0) for i in range(8)]
    assert trace == [4, 2, 2, 1, 1, 1, 1, 1]
    # mid-band rates hold depth and reset the hit streak
    c = DepthController(4, depth=2)
    assert [c.update(10, r) for r in (10, 6, 10, 6)] == [2, 2, 2, 2]
    # zero-draft dispatches (all slots prefilling) are no evidence: depth
    # *and* streak survive them
    c = DepthController(4, depth=1)
    assert c.update(4, 4) == 1
    assert c.update(0, 0) == 1
    assert c.update(4, 4) == 2      # streak was preserved across the gap


def test_depth_controller_validation_and_clamps():
    with pytest.raises(ValueError, match="n_max"):
        DepthController(0)
    assert DepthController(2, depth=5).depth == 2    # clamped into 1..n_max
    assert DepthController(3).depth == 3             # depth=0 -> start at max
    # static mode (thresholds outside [0,1]) never moves
    c = DepthController(4, lo=-1.0, hi=2.0)
    assert [c.update(4, a) for a in (4, 0, 4, 0)] == [4, 4, 4, 4]


def test_spec_depth_swing_zero_recompiles():
    """Depth is a runtime operand of the jitted dispatch: a garbage draft
    collapses the controller from n_spec to 1 *within* a serve, and a
    second (warm-prefix) serve swings it again from the top — the jit
    cache must hold exactly one traced signature per speculative entry
    point throughout (no shape drift, no weak-type literals)."""
    cfg, model, params, draft, bad, spec = _setup()
    prompts = _shared_prompts(spec, [18, 21, 18], share=16)
    eng = Engine(model, params, slots=2, cache_len=40, k_steps=4,
                 paged=True, block_size=8, chunk_size=8, prefix_cache=True,
                 n_spec=3, draft_params=bad, check_invariants=True)
    _, stats = eng.serve(prompts, gen_tokens=6, return_stats=True)
    assert stats["spec_depth"] == 1        # ~0% acceptance collapsed it
    counts = eng.compile_counts()
    spec_entries = [n for n in counts if "spec" in n]
    assert spec_entries
    if all(v >= 0 for v in counts.values()):   # cache-size probe available
        assert all(counts[n] <= 1 for n in spec_entries), counts
        eng.serve(prompts, gen_tokens=6)       # warm pass, fresh swing
        assert eng.compile_counts() == counts  # flat: zero recompiles


# ---------------------------------------------------------------------------
# alloc_span copy-on-write (the composed allocator primitive, in isolation)
# ---------------------------------------------------------------------------

def _shared_block_state():
    """Slot 0 holds block 0 — a partially-filled prompt block also
    referenced by the prefix index (ref 2); slot 1 is inactive.  Blocks
    1..3 are free."""
    b = init_block_state(2, 4, 4)
    return {**b,
            "tbl": b["tbl"].at[0, 0].set(0),
            "ref": b["ref"].at[0].set(2),
            "free": jnp.asarray([1, 2, 3, 0], jnp.int32),
            "n_free": jnp.int32(3),
            "slot_active": jnp.asarray([True, False])}


def test_alloc_span_cow_pops_rewires_and_reports():
    """A shared first span entry gets a private block popped, the table
    rewired, one reference dropped on the source, and the (src, dst) pair
    reported for the row copy; the inactive slot reports the no-copy
    sentinel (src == dst) and conservation holds."""
    b = _shared_block_state()
    out, src, dst, blocked = alloc_span(
        b, jnp.asarray([4, 0], jnp.int32), 2, 8, 32, False, cow=True)
    new = int(out["tbl"][0, 0])
    assert new != 0 and int(out["ref"][new]) == 1
    assert int(out["ref"][0]) == 1            # index still holds the source
    assert int(out["n_free"]) == 2
    assert (int(src[0]), int(dst[0])) == (0, new)
    assert int(src[1]) == int(dst[1])         # slot 1: nothing to copy
    assert not bool(blocked[0]) and not bool(blocked[1])
    assert int(out["n_free"]) + int(jnp.sum(out["ref"] > 0)) == 4


def test_alloc_span_cow_skips_private_blocks():
    """ref == 1 (a block this slot owns outright) is not shared: no pop,
    no copy pair, the table entry stays."""
    b = _shared_block_state()
    b = {**b, "ref": b["ref"].at[0].set(1)}
    out, src, dst, blocked = alloc_span(
        b, jnp.asarray([4, 0], jnp.int32), 2, 8, 32, False, cow=True)
    assert int(out["tbl"][0, 0]) == 0
    assert int(out["n_free"]) == 3
    assert int(src[0]) == int(dst[0])
    assert not bool(blocked[0])


def test_alloc_span_cow_spanning_into_fresh_block():
    """A span crossing from the shared block into unallocated territory
    pops two blocks in one call — a CoW replacement for entry 0 and a
    plain allocation for entry 1 — and decrements only the shared
    source."""
    b = _shared_block_state()
    out, src, dst, blocked = alloc_span(
        b, jnp.asarray([6, 0], jnp.int32), 4, 8, 32, False, cow=True)
    e0, e1 = int(out["tbl"][0, 0]), int(out["tbl"][0, 1])
    assert e0 != 0 and e1 >= 0 and e1 != e0
    assert int(out["ref"][0]) == 1 and int(out["ref"][e0]) == 1
    assert int(out["ref"][e1]) == 1
    assert int(out["n_free"]) == 1
    assert (int(src[0]), int(dst[0])) == (0, e0)
    assert int(out["n_free"]) + int(jnp.sum(out["ref"] > 0)) == 4


def test_alloc_span_cow_dry_pool_blocks_the_slot():
    """With the free stack empty a shared first entry cannot CoW: the
    slot is reported blocked, and *nothing* moves — table, refs and the
    stack are untouched, so the round can mask the slot out and retry."""
    b = _shared_block_state()
    b = {**b, "n_free": jnp.int32(0)}
    out, src, dst, blocked = alloc_span(
        b, jnp.asarray([4, 0], jnp.int32), 2, 8, 32, False, cow=True)
    assert bool(blocked[0]) and not bool(blocked[1])
    assert int(out["tbl"][0, 0]) == 0
    assert int(out["ref"][0]) == 2
    assert int(out["n_free"]) == 0
    assert int(src[0]) == int(dst[0])         # no copy while blocked


def test_alloc_span_ring_is_a_no_op():
    """Ring (SWA) tables are fully allocated at admission and never
    shared: the ring case pops nothing and reports no-copy sentinels."""
    b = _shared_block_state()
    out, src, dst, blocked = alloc_span(
        b, jnp.asarray([4, 0], jnp.int32), 2, 8, 32, True, cow=True)
    assert int(out["n_free"]) == 3
    assert np.asarray(src == dst).all()
    assert not np.asarray(blocked).any()


# ---------------------------------------------------------------------------
# Acceptance rules as pure functions
# ---------------------------------------------------------------------------

def test_greedy_accept_prefix_and_correction():
    p_logits = jnp.asarray([
        # verifier argmaxes: [2, 0, 3] — drafts [2, 0, 1]: accept 2, fix 3
        [[0, 1, 9, 2], [9, 1, 0, 2], [0, 1, 2, 9]],
        # verifier argmaxes: [1, 3, 0] — drafts [2, 3, 0]: reject at 0
        [[0, 9, 1, 2], [0, 1, 2, 9], [9, 1, 2, 0]],
    ], jnp.float32)
    drafts = jnp.asarray([[2, 0, 1], [2, 3, 0]], jnp.int32)
    out, a = greedy_accept(drafts[:, :2], p_logits)
    np.testing.assert_array_equal(np.asarray(a), [2, 0])
    assert out[0, 0] == 2 and out[0, 1] == 0 and out[0, 2] == 3  # bonus row
    assert out[1, 0] == 1                                       # correction


def test_rejection_accept_identical_draft_always_accepts():
    """q == p accepts every draft (the ratio test is >= 1) and the bonus
    comes from p_{n+1}."""
    V = 8
    k1, k2 = jax.random.split(KEY)
    p = jax.random.normal(k1, (4, 3, V))
    drafts = jax.random.categorical(k2, p[:, :2], axis=-1).astype(jnp.int32)
    sp = SamplingParams(greedy=False, temperature=0.8)
    out, a = rejection_accept(jax.random.PRNGKey(5), drafts, p[:, :2], p, sp)
    np.testing.assert_array_equal(np.asarray(a), [2, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(out[:, :2]), np.asarray(drafts))


def test_rejection_sampling_unbiased_on_toy_vocab():
    """The emitted first token of a speculative round must be distributed
    exactly as plain sampling from the warped target — for a draft
    distribution that disagrees with the target.  Empirical frequencies
    over a fixed-seed batch of rounds vs the exact warped target probs."""
    V, N = 6, 8000
    p_logits = jnp.asarray([[0.5, -0.2, 1.1, 0.0, -1.0, 0.4],
                            [1.0, 0.0, 0.0, -0.5, 0.3, -0.2],
                            [0.0, 0.2, -0.3, 0.8, 0.1, -0.9]], jnp.float32)
    q_logits = jnp.asarray([[1.2, 0.1, -0.5, 0.3, 0.0, -0.2],
                            [-0.3, 0.9, 0.2, 0.0, -1.0, 0.5]], jnp.float32)
    sp = SamplingParams(greedy=False, temperature=0.9, top_k=5, top_p=0.95)

    def one_round(key):
        kd, ka = jax.random.split(key)
        drafts = sample(q_logits, kd, sp)[None]            # [1, 2] from q
        out, _ = rejection_accept(ka, drafts, q_logits[None],
                                  p_logits[None], sp)
        return out[0, 0]                                   # first emitted

    toks = jax.vmap(one_round)(jax.random.split(jax.random.PRNGKey(42), N))
    freq = np.bincount(np.asarray(toks), minlength=V) / N
    want = np.asarray(probs(p_logits[0], sp))
    np.testing.assert_allclose(freq, want, atol=0.02)
    # and tokens cut by the warp never appear
    assert np.all(freq[want == 0] == 0)


# ---------------------------------------------------------------------------
# Sampler distribution correctness (temperature / top-k / top-p)
# ---------------------------------------------------------------------------

def test_sampler_frequency_matches_warped_distribution():
    """Fixed-seed frequency test: empirical sampling frequencies track the
    warped (top-k -> temperature -> top-p) distribution, and masked tokens
    have exactly zero mass."""
    V, N = 8, 8000
    logits = jnp.asarray([2.0, 1.5, 1.2, 0.8, 0.2, -0.5, -1.0, -3.0])
    cases = [
        SamplingParams(greedy=False, temperature=0.7),
        SamplingParams(greedy=False, temperature=1.3, top_k=4),
        SamplingParams(greedy=False, temperature=1.0, top_p=0.6),
        SamplingParams(greedy=False, temperature=0.8, top_k=5, top_p=0.8),
    ]
    for sp in cases:
        keys = jax.random.split(jax.random.PRNGKey(123), N)
        toks = jax.vmap(lambda k: sample(logits, k, sp))(keys)
        freq = np.bincount(np.asarray(toks), minlength=V) / N
        want = np.asarray(probs(logits, sp))
        np.testing.assert_allclose(freq, want, atol=0.02, err_msg=repr(sp))
        assert np.all(freq[want == 0] == 0), repr(sp)


def test_top_p_nucleus_boundary():
    """top_p keeps the smallest prefix of the sorted distribution whose
    mass reaches p — the top token always survives, even when its own
    probability exceeds p."""
    logits = jnp.log(jnp.asarray([0.5, 0.3, 0.15, 0.05]))
    p_small = probs(logits, SamplingParams(greedy=False, top_p=0.4))
    np.testing.assert_allclose(np.asarray(p_small), [1.0, 0, 0, 0],
                               atol=1e-6)
    p_mid = probs(logits, SamplingParams(greedy=False, top_p=0.6))
    assert np.asarray(p_mid)[2] == 0 and np.asarray(p_mid)[3] == 0
    np.testing.assert_allclose(np.asarray(p_mid)[:2], [0.625, 0.375],
                               atol=1e-4)
    # top_p=1 is bitwise the old sampler (no truncation)
    p_all = probs(logits, SamplingParams(greedy=False))
    assert np.all(np.asarray(p_all) > 0)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(greedy=False, top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(greedy=False, top_p=1.5)


# ---------------------------------------------------------------------------
# Config validation (early, friendly)
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    cfg, model, params, draft, _, spec = _setup()
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, slots=2, cache_len=32, n_spec=2,
               draft_params=draft)
    with pytest.raises(ValueError, match="n_spec must be < k_steps"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, k_steps=2, n_spec=2, draft_params=draft)
    with pytest.raises(ValueError, match="draft_params"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, n_spec=2)
    with pytest.raises(ValueError, match="draft_params without n_spec"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, draft_params=draft)


def test_spec_composes_with_prefix_and_chunking():
    """The former restriction is gone: n_spec composed with prefix_cache
    *and* chunk_size constructs, serves, and matches the non-speculative
    prefix engine — at the deepest draft (n_spec = k_steps - 1)."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [10, 13])
    base = Engine(model, params, slots=2, cache_len=48, k_steps=5,
                  paged=True, block_size=8, chunk_size=8, prefix_cache=True
                  ).serve(prompts, gen_tokens=4)
    eng = Engine(model, params, slots=2, cache_len=48, k_steps=5,
                 paged=True, block_size=8, chunk_size=8, prefix_cache=True,
                 n_spec=4, draft_params=draft, check_invariants=True)
    assert eng.serve(prompts, gen_tokens=4) == base


def test_spec_rejects_capacity_routed_moe():
    """The verify forward routes MoE dropless; a config whose decode path
    can drop tokens (capacity_factor * top_k < n_experts) could diverge
    from the non-speculative engine on an overflowing queue, so the engine
    refuses it early instead of silently weakening the lossless claim.
    (Construction fails before params are touched, so stubs suffice.)"""
    cfg = reduced(get_arch("deepseek-v3"))
    assert cfg.capacity_factor * cfg.top_k < cfg.n_experts  # droppy default
    model = build_model(cfg)
    with pytest.raises(ValueError, match="dropless"):
        Engine(model, {}, slots=2, cache_len=32, paged=True, block_size=8,
               n_spec=2, draft_params={"stub": True})


def test_swa_block_size_validation_is_early():
    """block_size not dividing the SWA window fails at Engine construction
    with a friendly message, not as a deep shape error at first serve."""
    cfg, model, params, _, _, spec = _setup("mixtral-8x22b", dropless=True)
    with pytest.raises(ValueError, match="must divide the sliding window"):
        Engine(model, params, slots=2, cache_len=34, paged=True,
               block_size=6)


# ---------------------------------------------------------------------------
# Quantized-head matmul (the draft's per-step hot op)
# ---------------------------------------------------------------------------

def test_matmul_t_matches_dequantized_head():
    """matmul_t (x @ w.T with the scales hoisted around the transpose)
    matches the dequantize-then-transpose reference for tensor/channel
    granularities, eq_scale epilogue included; block granularity falls
    back to the exact dequantize path."""
    from repro.core.formats import get_format
    from repro.core.granularity import absmax_scale, quantize_store
    from repro.quant_runtime import qlinear
    from repro.quant_runtime.qparams import QuantizedTensor

    fmt = get_format("fp8_e4m3")
    table = jax.random.normal(KEY, (40, 24), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 24), jnp.bfloat16)
    for gran, bs in (("tensor", 128), ("channel", 128), ("block", 16)):
        scale = absmax_scale(table, gran, fmt, bs)
        q = quantize_store(table, scale, gran, fmt, bs)
        for eq in (None, jnp.abs(jax.random.normal(
                jax.random.PRNGKey(1), (40,))) + 0.5):
            qt = QuantizedTensor(q, scale, fmt="fp8_e4m3", granularity=gran,
                                 block_size=bs, out_dtype="bfloat16",
                                 eq_scale=eq)
            got = qlinear.matmul_t(x, qt)
            want = jnp.matmul(x, qt.dequantize().T.astype(x.dtype))
            assert got.shape == want.shape == (2, 3, 40)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=0.05, atol=0.05,
                err_msg=f"{gran} eq={eq is not None}")
    # dense tables: bitwise the old resolve-transpose path
    got = qlinear.matmul_t(x, table)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.matmul(x, table.T.astype(x.dtype))))
