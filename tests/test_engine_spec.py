"""Self-speculative decoding tests: greedy token-exactness against the
non-speculative paged engine across every family (any draft, good or
terrible), rollback/allocator invariants under randomized stress, the
acceptance rules as pure functions, sampler distribution correctness
(temperature / top-k / top-p frequency + lossless rejection-sampling
unbiasedness), config validation, and the quantized-head matmul."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import QuantConfig, get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import (Engine, SamplingParams, blocks_for, greedy_accept,
                          probs, rejection_accept, sample)
from repro.models import build_model
from repro.quantize import quantize

KEY = jax.random.PRNGKey(0)

_BUILT: dict = {}


def _setup(arch="glm4-9b", dropless=False):
    """Model + params (+ a quantized absmax draft tree and a wrong-seed
    'bad' draft), cached per arch so jit caches stay warm."""
    key = (arch, dropless)
    if key not in _BUILT:
        cfg = reduced(get_arch(arch))
        if dropless:
            cfg = dataclasses.replace(cfg,
                                      capacity_factor=float(cfg.n_experts))
        model = build_model(cfg)
        params = model.init(KEY)
        draft, _ = quantize(params, None,
                            QuantConfig(method="absmax",
                                        granularity="channel"),
                            mode="storage", out_dtype="bfloat16")
        bad = model.init(jax.random.PRNGKey(99))
        _BUILT[key] = (cfg, model, params, draft, bad,
                       LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[key]


def _prompts(spec, lens, seed=0):
    return [sample_batch(jax.random.PRNGKey(seed * 1000 + i), spec, 1, L)[0]
            for i, L in enumerate(lens)]


# ---------------------------------------------------------------------------
# Greedy token-exactness: spec == non-spec paged engine, every family
# ---------------------------------------------------------------------------

def test_spec_token_exact_matrix():
    """Speculative greedy output must equal the non-speculative paged
    engine token for token on dense, SWA-ring+MoE, MoE, pure-SSM and
    hybrid configs (MoE at dropless capacity, as for chunked prefill: the
    verify chunk routes dropless by construction).  The draft is a real
    absmax-quantized tree, so rounds mix accepts and rejections; the
    acceptance rate must be meaningful (> 0) for a draft this close."""
    cases = [
        ("glm4-9b", False, [10, 25, 6, 17], 40),
        ("mixtral-8x22b", True, [9, 21, 9, 14], 34),   # SWA ring + MoE
        ("deepseek-v3", True, [9, 21, 14], 34),        # MoE, prefix stack
        ("mamba2-780m", False, [9, 40, 12], 48),       # pure SSM
        ("jamba-v0.1-52b", True, [9, 40, 12], 48),     # hybrid
    ]
    for arch, moe, lens, cache_len in cases:
        cfg, model, params, draft, _, spec = _setup(arch, dropless=moe)
        prompts = _prompts(spec, lens)
        base = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8
                      ).serve(prompts, gen_tokens=5)
        seng = Engine(model, params, slots=2, cache_len=cache_len,
                      k_steps=3, paged=True, block_size=8, n_spec=2,
                      draft_params=draft, check_invariants=True)
        outs, stats = seng.serve(prompts, gen_tokens=5, return_stats=True)
        assert outs == base, arch
        assert stats["draft_tokens"] > 0
        assert 0 < stats["draft_accepted"] <= stats["draft_tokens"], arch


def test_spec_exact_for_any_draft_even_garbage():
    """The lossless guarantee is structural: a draft from a completely
    different seed (≈0% acceptance → a rollback every round) must still
    reproduce the non-speculative greedy output exactly — the draft only
    chooses how many verifier-identical tokens emit per round."""
    cfg, model, params, _, bad, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6, 9])
    base = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                  paged=True, block_size=8).serve(prompts, gen_tokens=6)
    outs, stats = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                         paged=True, block_size=8, n_spec=2,
                         draft_params=bad, check_invariants=True
                         ).serve(prompts, gen_tokens=6, return_stats=True)
    assert outs == base
    # wrong-seed drafts agree with the verifier about nothing
    assert stats["draft_accepted"] < stats["draft_tokens"] // 4


def test_spec_budget_clamp_edges():
    """A round can accept past the remaining budget; emission is clamped
    without changing values.  gen=1 never decodes, gen=2 clamps the very
    first round (n_spec=3 > remaining=1)."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6, 9])
    for gen in (1, 2, 4):
        base = Engine(model, params, slots=2, cache_len=32, k_steps=5,
                      paged=True, block_size=8).serve(prompts,
                                                      gen_tokens=gen)
        outs = Engine(model, params, slots=2, cache_len=32, k_steps=5,
                      paged=True, block_size=8, n_spec=3,
                      draft_params=draft, check_invariants=True
                      ).serve(prompts, gen_tokens=gen)
        assert outs == base, gen
        assert [len(o) for o in outs] == [gen] * len(prompts)


def test_spec_tight_pool_with_reservation_slack():
    """The reservation ledger counts the speculative span (up to n_spec
    rows past the budget) into each slot's worst case: a pool sized to
    exactly that bound serializes but stays exact and never starves."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [20, 20, 20, 20])
    base = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                  paged=True, block_size=8).serve(prompts, gen_tokens=5)
    need = blocks_for(20 + 5 - 1 + 2, 8)          # lifetime + n_spec slack
    tight = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                   paged=True, block_size=8, num_blocks=need, n_spec=2,
                   draft_params=draft, check_invariants=True)
    outs, stats = tight.serve(prompts, gen_tokens=5, return_stats=True)
    assert outs == base
    assert stats["prefill_calls"] == 4            # one slot at a time fits


def test_spec_sampled_mode_deterministic_and_complete():
    """Sampled speculative serving is not token-exact vs non-speculative
    sampling (different PRNG consumption) but must be deterministic under
    a fixed seed and deliver full budgets of in-vocab tokens."""
    cfg, model, params, draft, _, spec = _setup()
    prompts = _prompts(spec, [10, 13, 6])
    sp = SamplingParams(greedy=False, temperature=0.9, top_k=40, top_p=0.9)
    eng = Engine(model, params, slots=2, cache_len=32, k_steps=4,
                 paged=True, block_size=8, n_spec=2, draft_params=draft,
                 sampling=sp, check_invariants=True)
    o1 = eng.serve(prompts, gen_tokens=6, seed=7)
    o2 = eng.serve(prompts, gen_tokens=6, seed=7)
    assert o1 == o2
    assert [len(o) for o in o1] == [6, 6, 6]
    assert all(0 <= t < cfg.vocab_size for o in o1 for t in o)


# ---------------------------------------------------------------------------
# Randomized stress: mixed accept/reject rollbacks + allocator invariants
# ---------------------------------------------------------------------------

@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_spec_stress_randomized(seed):
    """Adversarial sweep: random prompt lengths / request counts / budgets
    / draft depth / pool tightness, alternating a close (quantized) and a
    hostile (wrong-seed) draft — so rounds mix full accepts, partial
    rollbacks and full rejections while slots churn and blocks recycle.
    Output must equal the non-speculative paged engine token for token,
    with allocator conservation asserted after every dispatch
    (check_invariants)."""
    rng = np.random.RandomState(seed)
    cfg, model, params, draft, bad, spec = _setup()
    slots = 2
    n_req = int(rng.randint(slots, slots + 4))
    lens = [int(rng.randint(4, 29)) for _ in range(n_req)]
    gen = int(rng.randint(2, 7))
    k_steps = int(rng.randint(2, 4))
    n_spec = int(rng.randint(1, k_steps))          # < k_steps
    cache_len = max(lens) + gen + int(rng.randint(0, 6))
    dtree = draft if seed % 2 == 0 else bad
    prompts = _prompts(spec, lens, seed=seed % 997)

    base = Engine(model, params, slots=slots, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=8
                  ).serve(prompts, gen_tokens=gen)
    full = slots * blocks_for(cache_len, 8)
    lo = max(blocks_for(L + gen - 1 + n_spec, 8) for L in lens)
    num_blocks = int(rng.randint(lo, full + 1))    # sometimes starved pool
    outs = Engine(model, params, slots=slots, cache_len=cache_len,
                  k_steps=k_steps, paged=True, block_size=8,
                  num_blocks=num_blocks, n_spec=n_spec, draft_params=dtree,
                  check_invariants=True).serve(prompts, gen_tokens=gen)
    assert outs == base


# ---------------------------------------------------------------------------
# Acceptance rules as pure functions
# ---------------------------------------------------------------------------

def test_greedy_accept_prefix_and_correction():
    p_logits = jnp.asarray([
        # verifier argmaxes: [2, 0, 3] — drafts [2, 0, 1]: accept 2, fix 3
        [[0, 1, 9, 2], [9, 1, 0, 2], [0, 1, 2, 9]],
        # verifier argmaxes: [1, 3, 0] — drafts [2, 3, 0]: reject at 0
        [[0, 9, 1, 2], [0, 1, 2, 9], [9, 1, 2, 0]],
    ], jnp.float32)
    drafts = jnp.asarray([[2, 0, 1], [2, 3, 0]], jnp.int32)
    out, a = greedy_accept(drafts[:, :2], p_logits)
    np.testing.assert_array_equal(np.asarray(a), [2, 0])
    assert out[0, 0] == 2 and out[0, 1] == 0 and out[0, 2] == 3  # bonus row
    assert out[1, 0] == 1                                       # correction


def test_rejection_accept_identical_draft_always_accepts():
    """q == p accepts every draft (the ratio test is >= 1) and the bonus
    comes from p_{n+1}."""
    V = 8
    k1, k2 = jax.random.split(KEY)
    p = jax.random.normal(k1, (4, 3, V))
    drafts = jax.random.categorical(k2, p[:, :2], axis=-1).astype(jnp.int32)
    sp = SamplingParams(greedy=False, temperature=0.8)
    out, a = rejection_accept(jax.random.PRNGKey(5), drafts, p[:, :2], p, sp)
    np.testing.assert_array_equal(np.asarray(a), [2, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(out[:, :2]), np.asarray(drafts))


def test_rejection_sampling_unbiased_on_toy_vocab():
    """The emitted first token of a speculative round must be distributed
    exactly as plain sampling from the warped target — for a draft
    distribution that disagrees with the target.  Empirical frequencies
    over a fixed-seed batch of rounds vs the exact warped target probs."""
    V, N = 6, 8000
    p_logits = jnp.asarray([[0.5, -0.2, 1.1, 0.0, -1.0, 0.4],
                            [1.0, 0.0, 0.0, -0.5, 0.3, -0.2],
                            [0.0, 0.2, -0.3, 0.8, 0.1, -0.9]], jnp.float32)
    q_logits = jnp.asarray([[1.2, 0.1, -0.5, 0.3, 0.0, -0.2],
                            [-0.3, 0.9, 0.2, 0.0, -1.0, 0.5]], jnp.float32)
    sp = SamplingParams(greedy=False, temperature=0.9, top_k=5, top_p=0.95)

    def one_round(key):
        kd, ka = jax.random.split(key)
        drafts = sample(q_logits, kd, sp)[None]            # [1, 2] from q
        out, _ = rejection_accept(ka, drafts, q_logits[None],
                                  p_logits[None], sp)
        return out[0, 0]                                   # first emitted

    toks = jax.vmap(one_round)(jax.random.split(jax.random.PRNGKey(42), N))
    freq = np.bincount(np.asarray(toks), minlength=V) / N
    want = np.asarray(probs(p_logits[0], sp))
    np.testing.assert_allclose(freq, want, atol=0.02)
    # and tokens cut by the warp never appear
    assert np.all(freq[want == 0] == 0)


# ---------------------------------------------------------------------------
# Sampler distribution correctness (temperature / top-k / top-p)
# ---------------------------------------------------------------------------

def test_sampler_frequency_matches_warped_distribution():
    """Fixed-seed frequency test: empirical sampling frequencies track the
    warped (top-k -> temperature -> top-p) distribution, and masked tokens
    have exactly zero mass."""
    V, N = 8, 8000
    logits = jnp.asarray([2.0, 1.5, 1.2, 0.8, 0.2, -0.5, -1.0, -3.0])
    cases = [
        SamplingParams(greedy=False, temperature=0.7),
        SamplingParams(greedy=False, temperature=1.3, top_k=4),
        SamplingParams(greedy=False, temperature=1.0, top_p=0.6),
        SamplingParams(greedy=False, temperature=0.8, top_k=5, top_p=0.8),
    ]
    for sp in cases:
        keys = jax.random.split(jax.random.PRNGKey(123), N)
        toks = jax.vmap(lambda k: sample(logits, k, sp))(keys)
        freq = np.bincount(np.asarray(toks), minlength=V) / N
        want = np.asarray(probs(logits, sp))
        np.testing.assert_allclose(freq, want, atol=0.02, err_msg=repr(sp))
        assert np.all(freq[want == 0] == 0), repr(sp)


def test_top_p_nucleus_boundary():
    """top_p keeps the smallest prefix of the sorted distribution whose
    mass reaches p — the top token always survives, even when its own
    probability exceeds p."""
    logits = jnp.log(jnp.asarray([0.5, 0.3, 0.15, 0.05]))
    p_small = probs(logits, SamplingParams(greedy=False, top_p=0.4))
    np.testing.assert_allclose(np.asarray(p_small), [1.0, 0, 0, 0],
                               atol=1e-6)
    p_mid = probs(logits, SamplingParams(greedy=False, top_p=0.6))
    assert np.asarray(p_mid)[2] == 0 and np.asarray(p_mid)[3] == 0
    np.testing.assert_allclose(np.asarray(p_mid)[:2], [0.625, 0.375],
                               atol=1e-4)
    # top_p=1 is bitwise the old sampler (no truncation)
    p_all = probs(logits, SamplingParams(greedy=False))
    assert np.all(np.asarray(p_all) > 0)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(greedy=False, top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(greedy=False, top_p=1.5)


# ---------------------------------------------------------------------------
# Config validation (early, friendly)
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    cfg, model, params, draft, _, spec = _setup()
    with pytest.raises(ValueError, match="paged"):
        Engine(model, params, slots=2, cache_len=32, n_spec=2,
               draft_params=draft)
    with pytest.raises(ValueError, match="chunked prefill"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, chunk_size=8, n_spec=2, draft_params=draft)
    with pytest.raises(ValueError, match="n_spec must be < k_steps"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, k_steps=2, n_spec=2, draft_params=draft)
    with pytest.raises(ValueError, match="draft_params"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, n_spec=2)
    with pytest.raises(ValueError, match="draft_params without n_spec"):
        Engine(model, params, slots=2, cache_len=32, paged=True,
               block_size=8, draft_params=draft)


def test_spec_rejects_capacity_routed_moe():
    """The verify forward routes MoE dropless; a config whose decode path
    can drop tokens (capacity_factor * top_k < n_experts) could diverge
    from the non-speculative engine on an overflowing queue, so the engine
    refuses it early instead of silently weakening the lossless claim.
    (Construction fails before params are touched, so stubs suffice.)"""
    cfg = reduced(get_arch("deepseek-v3"))
    assert cfg.capacity_factor * cfg.top_k < cfg.n_experts  # droppy default
    model = build_model(cfg)
    with pytest.raises(ValueError, match="dropless"):
        Engine(model, {}, slots=2, cache_len=32, paged=True, block_size=8,
               n_spec=2, draft_params={"stub": True})


def test_swa_block_size_validation_is_early():
    """block_size not dividing the SWA window fails at Engine construction
    with a friendly message, not as a deep shape error at first serve."""
    cfg, model, params, _, _, spec = _setup("mixtral-8x22b", dropless=True)
    with pytest.raises(ValueError, match="must divide the sliding window"):
        Engine(model, params, slots=2, cache_len=34, paged=True,
               block_size=6)


# ---------------------------------------------------------------------------
# Quantized-head matmul (the draft's per-step hot op)
# ---------------------------------------------------------------------------

def test_matmul_t_matches_dequantized_head():
    """matmul_t (x @ w.T with the scales hoisted around the transpose)
    matches the dequantize-then-transpose reference for tensor/channel
    granularities, eq_scale epilogue included; block granularity falls
    back to the exact dequantize path."""
    from repro.core.formats import get_format
    from repro.core.granularity import absmax_scale, quantize_store
    from repro.quant_runtime import qlinear
    from repro.quant_runtime.qparams import QuantizedTensor

    fmt = get_format("fp8_e4m3")
    table = jax.random.normal(KEY, (40, 24), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 24), jnp.bfloat16)
    for gran, bs in (("tensor", 128), ("channel", 128), ("block", 16)):
        scale = absmax_scale(table, gran, fmt, bs)
        q = quantize_store(table, scale, gran, fmt, bs)
        for eq in (None, jnp.abs(jax.random.normal(
                jax.random.PRNGKey(1), (40,))) + 0.5):
            qt = QuantizedTensor(q, scale, fmt="fp8_e4m3", granularity=gran,
                                 block_size=bs, out_dtype="bfloat16",
                                 eq_scale=eq)
            got = qlinear.matmul_t(x, qt)
            want = jnp.matmul(x, qt.dequantize().T.astype(x.dtype))
            assert got.shape == want.shape == (2, 3, 40)
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=0.05, atol=0.05,
                err_msg=f"{gran} eq={eq is not None}")
    # dense tables: bitwise the old resolve-transpose path
    got = qlinear.matmul_t(x, table)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.matmul(x, table.T.astype(x.dtype))))
