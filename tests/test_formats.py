"""Unit + property tests: number formats and scale granularities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.formats import FORMATS, dequantize, get_format, qdq, quantize
from repro.core.granularity import (absmax_scale, apply_qdq, dequantize_stored,
                                    from_blocked, pad_to_blocks, quantize_store,
                                    to_blocked)

FMT_NAMES = sorted(FORMATS)


@pytest.mark.parametrize("fmt_name", FMT_NAMES)
def test_qdq_idempotent(fmt_name):
    """Quantizing an already-quantized tensor is a fixed point."""
    fmt = get_format(fmt_name)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.1
    scale = jnp.float32(jnp.max(jnp.abs(w)) / fmt.qmax)
    w1 = qdq(w, scale, fmt)
    w2 = qdq(w1, scale, fmt)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=0, atol=0)


@pytest.mark.parametrize("fmt_name", FMT_NAMES)
def test_quantize_saturates(fmt_name):
    fmt = get_format(fmt_name)
    w = jnp.array([[1e6, -1e6, 0.0, 1e-12]])
    q = quantize(w, jnp.float32(1.0), fmt)
    dq = dequantize(q, jnp.float32(1.0), fmt)
    assert float(jnp.max(jnp.abs(dq))) <= fmt.qmax
    assert np.isfinite(np.asarray(dq, np.float32)).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(2, 40), st.sampled_from([4, 8, 16]))
def test_block_roundtrip(i, o, bs):
    w = np.random.RandomState(i * 100 + o).randn(i, o).astype(np.float32)
    wp, orig = pad_to_blocks(jnp.asarray(w), bs)
    back = from_blocked(to_blocked(wp, bs), orig)
    np.testing.assert_array_equal(np.asarray(back), w)


@pytest.mark.parametrize("gran", ["tensor", "channel", "block"])
def test_absmax_scale_covers_range(gran):
    """AbsMax scales never clip: |W/s| <= qmax everywhere."""
    fmt = get_format("fp8_e4m3")
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 80)) * 3.0
    s = absmax_scale(w, gran, fmt, block_size=32)
    if gran == "block":
        wp, _ = pad_to_blocks(w, 32)
        ratio = jnp.abs(to_blocked(wp, 32)) / s
    else:
        ratio = jnp.abs(w) / s
    assert float(jnp.max(ratio)) <= fmt.qmax * (1 + 1e-6)


@pytest.mark.parametrize("gran", ["tensor", "channel", "block"])
@pytest.mark.parametrize("fmt_name", ["fp8_e4m3", "int8", "int4"])
def test_store_dequant_matches_qdq(gran, fmt_name):
    """storage-repr -> dequant == direct qdq (same numerics both paths)."""
    fmt = get_format(fmt_name)
    w = jax.random.normal(jax.random.PRNGKey(2), (65, 48)) * 0.2
    s = absmax_scale(w, gran, fmt, block_size=32)
    direct = apply_qdq(w, s, gran, fmt, 32)
    q = quantize_store(w, s, gran, fmt, 32)
    via_store = dequantize_stored(q, s, gran, fmt, 32, jnp.float32)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(via_store),
                               atol=1e-6)


def test_qdq_error_bounded_fp8():
    """Relative qdq error of E4M3 under absmax scaling is < 2^-3."""
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 128))
    fmt = get_format("fp8_e4m3")
    s = absmax_scale(w, "tensor", fmt)
    err = jnp.abs(qdq(w, s, fmt) - w)
    # elementwise: error <= max(2^-4 * |w|... use 2^-3 * |w| + tiny denormal slack
    bound = jnp.maximum(0.125 * jnp.abs(w), float(s) * 0.002)
    assert bool(jnp.all(err <= bound + 1e-7))
