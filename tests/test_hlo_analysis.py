"""Trip-count-aware HLO cost parser vs analytic ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import HloModule, analyze


def test_nested_scan_flops_exact():
    def f(xs, w):
        def body(c, x):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, jnp.arange(5))
            return c2 + x, jnp.sum(c2)
        return jax.lax.scan(body, xs[0], xs)

    xs = jax.ShapeDtypeStruct((40, 64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, w).compile()
    res = analyze(compiled.as_text(), 1)
    expected = 40 * 5 * 2 * 64 ** 3
    np.testing.assert_allclose(res["flops"], expected, rtol=1e-2)


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    res = analyze(compiled.as_text(), 1)
    np.testing.assert_allclose(res["flops"], 2 * 128 * 256 * 64, rtol=1e-6)


def test_bytes_at_least_io():
    """Traffic proxy >= inputs + outputs."""
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    compiled = jax.jit(lambda a: jnp.tanh(a) * 2).lower(a).compile()
    res = analyze(compiled.as_text(), 1)
    assert res["bytes"] >= 2 * 512 * 512 * 4


def test_multiplier_propagation():
    txt = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %r = pred[] constant(false)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%c, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    mod = HloModule(txt)
    np.testing.assert_allclose(mod.flops(), 12 * 2 * 8 ** 3, rtol=1e-6)


def test_collectives_parsed():
    import os
    # build a tiny sharded program in-process only if >1 device; else skip
    if len(jax.devices()) < 2:
        import pytest
        pytest.skip("single device")
