"""Trip-count-aware HLO cost parser vs analytic ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import HloModule, analyze


def test_nested_scan_flops_exact():
    def f(xs, w):
        def body(c, x):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, jnp.arange(5))
            return c2 + x, jnp.sum(c2)
        return jax.lax.scan(body, xs[0], xs)

    xs = jax.ShapeDtypeStruct((40, 64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, w).compile()
    res = analyze(compiled.as_text(), 1)
    expected = 40 * 5 * 2 * 64 ** 3
    np.testing.assert_allclose(res["flops"], expected, rtol=1e-2)


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    res = analyze(compiled.as_text(), 1)
    np.testing.assert_allclose(res["flops"], 2 * 128 * 256 * 64, rtol=1e-6)


def test_bytes_at_least_io():
    """Traffic proxy >= inputs + outputs."""
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    compiled = jax.jit(lambda a: jnp.tanh(a) * 2).lower(a).compile()
    res = analyze(compiled.as_text(), 1)
    assert res["bytes"] >= 2 * 512 * 512 * 4


def test_multiplier_propagation():
    txt = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %r = pred[] constant(false)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%c, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    mod = HloModule(txt)
    np.testing.assert_allclose(mod.flops(), 12 * 2 * 8 ** 3, rtol=1e-6)


def test_collectives_parsed():
    import os
    # build a tiny sharded program in-process only if >1 device; else skip
    if len(jax.devices()) < 2:
        import pytest
        pytest.skip("single device")


# -- input_output_alias + host-op parsing (repro.staticcheck substrate) ------

def test_alias_header_parsed_from_text():
    from repro.analysis.hlo import parse_input_output_aliases
    txt = ('HloModule m, input_output_alias={ {0}: (1, {}, may-alias), '
           '{1, 2}: (0, {3}, must-alias) }\n\n'
           'ENTRY %main (a: f32[4], b: f32[4]) -> (f32[4], f32[4]) {\n'
           '  ROOT %t = (f32[4], f32[4]) tuple(%a, %b)\n}\n')
    aliases = parse_input_output_aliases(txt)
    assert aliases == [
        {"output_index": (0,), "param_number": 1, "param_index": (),
         "kind": "may-alias"},
        {"output_index": (1, 2), "param_number": 0, "param_index": (3,),
         "kind": "must-alias"},
    ]
    assert HloModule(txt).aliased_param_numbers() == {0, 1}


def test_alias_absent_when_no_donation():
    txt = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile().as_text()
    mod = HloModule(txt)
    assert mod.aliased_param_numbers() == set()
    assert mod.entry_params() == {0: "f32[16,16]{1,0}", 1: "f32[16,16]{1,0}"}
    assert mod.param_bytes(0) == 16 * 16 * 4


def test_alias_of_compiled_donation():
    fn = jax.jit(lambda c, x: c.at[0].set(x), donate_argnums=(0,))
    txt = fn.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                   jax.ShapeDtypeStruct((32,), jnp.float32)) \
        .compile().as_text()
    assert 0 in HloModule(txt).aliased_param_numbers()


def test_host_ops_detects_callback_and_clean_module():
    def f(x):
        jax.debug.print("s={}", jnp.sum(x))
        return x + 1
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    hits = HloModule(txt).host_ops()
    assert hits and any("callback" in t for _, _, t in hits)

    clean = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
    assert HloModule(clean).host_ops() == []


def test_host_ops_detects_infeed_ops_in_text():
    txt = ('HloModule m\n\n'
           'ENTRY %main (a: f32[4]) -> f32[4] {\n'
           '  %tok = token[] after-all()\n'
           '  %i = (f32[4], token[]) infeed(%tok)\n'
           '  ROOT %g = f32[4] get-tuple-element(%i), index=0\n}\n')
    assert [op for _, op, _ in HloModule(txt).host_ops()] == ["infeed"]
