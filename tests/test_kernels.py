"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fp8_matmul.kernel import matmul_fp8_pallas
from repro.kernels.fp8_matmul.ref import matmul_fp8_ref
from repro.kernels.fp8_quant.kernel import quantize_fp8_pallas
from repro.kernels.fp8_quant.ops import quantize_fp8
from repro.kernels.fp8_quant.ref import quantize_fp8_ref
from repro.kernels.scale_search.kernel import sweep_partials_pallas
from repro.kernels.scale_search.ref import sweep_partials_ref


@pytest.mark.parametrize("shape,bs", [((256, 128), 128), ((128, 256), 64),
                                      ((384, 384), 128), ((64, 64), 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scale_search_kernel(shape, bs, dtype):
    I, O = shape
    key = jax.random.PRNGKey(I + O)
    wb = (jax.random.normal(key, shape) * 0.05).astype(dtype)
    wp = wb + (jax.random.normal(jax.random.PRNGKey(1), shape)
               * 0.002).astype(dtype)
    wp32, wb32 = wp.astype(jnp.float32), wb.astype(jnp.float32)
    alphas = jnp.linspace(0.8, 1.25, 4)
    nbi, nbo = I // bs, O // bs
    amax = jnp.max(jnp.abs(wp32.reshape(nbi, bs, nbo, bs)), axis=(1, 3))
    s0 = jnp.maximum(amax, 1e-12) / 448.0
    pk = sweep_partials_pallas(wp32, wb32, s0, alphas, block_size=bs,
                               interpret=True)
    pr = sweep_partials_ref(wp32, wb32, s0, alphas, block_size=bs)
    # Tolerances: the sign-match stat is an integer count; fp32
    # associativity / division-order can flip exact-tie elements (bf16
    # inputs produce many exact boundary deltas).  Counts agree to <1%;
    # all continuous stats agree to 1e-4 relative.
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr),
                               rtol=1.2e-2, atol=2.5)
    cont = [0, 2, 3, 4]  # sq_err, dot, dp_sq, dq_sq
    np.testing.assert_allclose(np.asarray(pk)[..., cont],
                               np.asarray(pr)[..., cont],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(64, 256, 256), (128, 128, 384),
                                   (32, 256, 128), (8, 128, 128)])
@pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
def test_fp8_matmul_kernel(M, K, N, xdtype):
    key = jax.random.PRNGKey(M * K + N)
    x = jax.random.normal(key, (M, K)).astype(xdtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    q, s = quantize_fp8(w)
    yk = matmul_fp8_pallas(x, q, s, bm=min(128, M), block=128, interpret=True)
    yr = matmul_fp8_ref(x, q, s, block=128)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("shape,b", [((256, 256), 128), ((128, 384), 128),
                                     ((256, 128), 64), ((64, 192), 64)])
def test_fp8_quant_kernel(shape, b):
    w = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.3
    qk, sk = quantize_fp8_pallas(w, jnp.ones(1), block=b, interpret=True)
    qr, sr = quantize_fp8_ref(w, jnp.ones(1), block=b)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    a = np.asarray(qk, np.float32)
    r = np.asarray(qr, np.float32)
    neq = a != r
    # 1-ulp division differences may flip an fp8 bucket for boundary values
    assert neq.mean() < 1e-4, f"{neq.sum()} mismatches"


def test_fp8_quant_ragged_padding():
    """ops wrapper pads ragged shapes and returns the original layout."""
    w = jax.random.normal(jax.random.PRNGKey(2), (130, 70)) * 0.1
    q, s = quantize_fp8(w, block=64)
    assert q.shape == (130, 70)
    assert s.shape == (-(-130 // 64), -(-70 // 64))
    # dequant error bounded by fp8 resolution
    nbi, nbo = s.shape
    # reconstruct with block scales
    wpad = jnp.pad(w, ((0, 128 - 130 % 128 if False else (-130) % 64),
                       (0, (-70) % 64)))
    dq = (jnp.pad(q.astype(jnp.float32), (((0), (-130) % 64), (0, (-70) % 64)))
          .reshape(nbi, 64, nbo, 64) * s[:, None, :, None]).reshape(
              nbi * 64, nbo * 64)[:130, :70]
    err = jnp.abs(dq - w)
    assert float(jnp.max(err / (jnp.abs(w) + 1e-3))) < 0.2


def test_flash_attention_vs_naive():
    """models/flash.py fwd + grad vs a dense softmax oracle."""
    from repro.models.attention import chunked_attention
    B, S, H, Kv, hd = 2, 24, 4, 2, 8
    G = H // Kv
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))

    def naive(q, k, v, causal, window, cap):
        kr = jnp.repeat(k, G, 2)
        vr = jnp.repeat(v, G, 2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(hd)
        if cap:
            s = cap * jnp.tanh(s / cap)
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        m = jnp.ones((S, S), bool)
        if causal:
            m = m & (kp <= qp)
        if window:
            m = m & (kp > qp - window)
        s = jnp.where(m[None, None], s, -1e30)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)

    for causal, window, cap in [(True, 0, 0.0), (True, 7, 0.0),
                                (False, 0, 0.0), (True, 0, 5.0)]:
        fa = lambda q, k, v: chunked_attention(
            q, k, v, causal=causal, window=window, softcap=cap,
            q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(
            np.asarray(fa(q, k, v)),
            np.asarray(naive(q, k, v, causal, window, cap)),
            rtol=1e-4, atol=1e-5)
        g1 = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(fa(q, k, v))),
                      (0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(
            naive(q, k, v, causal, window, cap))), (0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=3e-5)
