"""Delta-aware metric properties (paper Sec. 2.3)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import metrics as M


def _rand(seed, shape=(32, 16), scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def test_eq7_identity():
    """Paper Eq. 7: delta-MSE == weight-MSE (base model cancels)."""
    wb, wp = _rand(0), _rand(1)
    wq = _rand(2)
    lhs = M.mse(wp - wb, wq - wb)       # delta framing
    rhs = jnp.mean((wq - wp) ** 2)       # direct reconstruction
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-5)


def test_metric_ranges():
    dp, dq = _rand(3), _rand(4)
    assert 0.0 <= float(M.sign_rate(dp, dq)) <= 1.0
    assert -1.0 - 1e-6 <= float(M.cosine(dp, dq)) <= 1.0 + 1e-6
    assert float(M.mse(dp, dq)) >= 0.0


def test_perfect_preservation():
    dp = _rand(5)
    assert float(M.sign_rate(dp, dp)) == 1.0
    np.testing.assert_allclose(float(M.cosine(dp, dp)), 1.0, rtol=1e-6)
    assert float(M.mse(dp, dp)) == 0.0
    np.testing.assert_allclose(float(M.cosine(dp, -dp)), -1.0, rtol=1e-6)


def test_sign_zero_convention():
    """sign(0) = 0 participates: zero deltas only match zero deltas."""
    dp = jnp.array([0.0, 0.0, 1.0, -1.0])
    dq = jnp.array([0.0, 1.0, 1.0, 1.0])
    assert float(M.sign_rate(dp, dq)) == 0.5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_partial_sums_consistency(seed):
    """Whole-tensor metrics == metrics reconstructed from partial sums."""
    dp, dq = _rand(seed), _rand(seed + 1)
    parts = M.partial_sums(dp, dq, axes=tuple(range(dp.ndim)))
    rec = M.metrics_from_partials(parts)
    np.testing.assert_allclose(float(rec["mse"]), float(M.mse(dp, dq)),
                               rtol=1e-5)
    np.testing.assert_allclose(float(rec["sign_rate"]),
                               float(M.sign_rate(dp, dq)), rtol=1e-6)
    np.testing.assert_allclose(float(rec["cosine"]),
                               float(M.cosine(dp, dq)), rtol=1e-5)


def test_objective_direction():
    """objective() is maximization-consistent for every metric."""
    dp = _rand(6)
    good, bad = dp, -dp
    for m in ("mse", "sign", "cosine", "hybrid"):
        assert float(M.objective(m, dp, good)) > float(M.objective(m, dp, bad))


def test_cosine_scale_invariant():
    dp, dq = _rand(7), _rand(8)
    c1 = float(M.cosine(dp, dq))
    c2 = float(M.cosine(dp, 3.7 * dq))
    np.testing.assert_allclose(c1, c2, rtol=1e-5)
