"""Per-arch smoke tests (assignment deliverable f) + cache consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch, reduced
from repro.models import build_model

B, S = 2, 48
KEY = jax.random.PRNGKey(0)

ARCH_IDS = [c.name for c in ASSIGNED]


def _batch(cfg, seq=S):
    b = {"tokens": jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size),
         "labels": jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(KEY, (B, 24, cfg.d_model),
                                        jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    """Reduced config: one forward + loss; shapes + finiteness."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(model.loss_fn)(params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert float(metrics["n_tokens"]) == B * S
    # one train-grad step: finite grads on every leaf
    g = jax.grad(lambda p: model.loss_fn(p, _batch(cfg))[0])(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), (arch, path)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode(arch):
    """prefill -> decode_step produces [B, V] finite logits, cache advances."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    b = _batch(cfg)
    del b["labels"]
    logits, cache = model.prefill(params, b, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, cache2 = jax.jit(model.decode_step)(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache2["lengths"][0]) == S + 1


@pytest.mark.parametrize("arch", ["glm4-9b", "mamba2-780m", "jamba-v0.1-52b",
                                  "seamless-m4t-medium"])
def test_decode_matches_prefill(arch):
    """Decoding token S from a cache == prefilling S+1 tokens directly."""
    cfg = reduced(get_arch(arch))
    if cfg.n_experts:  # capacity dropping differs between paths
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(KEY, (B, 24, cfg.d_model),
                                            jnp.bfloat16)
    _, cache = model.prefill(params, {"tokens": toks[:, :S], **extra},
                             cache_len=S + 8)
    la, _ = model.decode_step(params, toks[:, S:S + 1], cache)
    lb, _ = model.prefill(params, {"tokens": toks, **extra}, cache_len=S + 8)
    rel = float(jnp.max(jnp.abs(la - lb)) / (jnp.max(jnp.abs(lb)) + 1e-9))
    assert rel < 2e-2, (arch, rel)


def test_sliding_window_ring_cache():
    """SWA ring cache: decode past the window stays exact.

    Ring mechanics (prefill modulo population, _ring_write, eff_len
    masking) are isolated on a *dense* SWA config: on Mixtral the same
    comparison is limited by MoE top-2 routing, which is discrete — bf16
    decode-vs-prefill noise (~1%) can flip an expert choice at a narrow
    router margin and blow any logit tolerance (observed at total = W + 9,
    where the flip moves max-logit error from ~0.9% to 15% while the ring
    itself is bit-identical to alternative cache layouts)."""
    cfg = dataclasses.replace(reduced(get_arch("glm4-9b")), sliding_window=16)
    model = build_model(cfg)
    params = model.init(KEY)
    W = cfg.sliding_window
    total = W + 9
    toks = jax.random.randint(KEY, (B, total + 1), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :total]},
                             cache_len=W)  # ring-sized cache
    assert cache["stack"]["L0"]["k"].shape[2] == W
    la, _ = model.decode_step(params, toks[:, total:total + 1], cache)
    lb, _ = model.prefill(params, {"tokens": toks}, cache_len=total + 1)
    rel = float(jnp.max(jnp.abs(la - lb)) / (jnp.max(jnp.abs(lb)) + 1e-9))
    assert rel < 2e-2, rel

    # Mixtral rides the identical ring code path; pin the ring-sized cache
    # shape and decode finiteness, and token-level agreement within the
    # window (no wraparound yet, routing margins unchallenged).
    mcfg = dataclasses.replace(reduced(get_arch("mixtral-8x22b")),
                               capacity_factor=8.0)
    assert mcfg.sliding_window == 16
    mmodel = build_model(mcfg)
    mparams = mmodel.init(KEY)
    mtoks = jax.random.randint(KEY, (B, 13), 0, mcfg.vocab_size)
    _, mcache = mmodel.prefill(mparams, {"tokens": mtoks[:, :12]},
                               cache_len=mcfg.sliding_window)
    assert mcache["stack"]["L0"]["k"].shape[2] == mcfg.sliding_window
    ma, _ = mmodel.decode_step(mparams, mtoks[:, 12:13], mcache)
    mb, _ = mmodel.prefill(mparams, {"tokens": mtoks}, cache_len=13)
    assert np.isfinite(np.asarray(ma, np.float32)).all()
    assert jnp.array_equal(jnp.argmax(ma, -1), jnp.argmax(mb, -1))


def test_param_count_close_to_analytic():
    """Analytic param_count stays within 5% of the real tree (glm4 full)."""
    for arch in ("glm4-9b", "mixtral-8x22b", "mamba2-780m"):
        cfg = reduced(get_arch(arch))
        model = build_model(cfg)
        params = model.init(KEY)
        real = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(real - approx) / real < 0.05, (arch, real, approx)


def test_quantized_params_serve_same_code():
    """QuantizedTensor leaves run the identical decode path."""
    from repro.configs import QuantConfig
    from repro.core.daq import quantize_tree
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    base = jax.tree.map(lambda p: p * 0.99 if p.ndim >= 2 else p, params)
    qparams, _ = quantize_tree(params, base,
                               QuantConfig(granularity="channel"),
                               mode="storage", out_dtype="bfloat16")
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    l_dense, _ = model.prefill(params, b, cache_len=S)
    l_quant, cache = model.prefill(qparams, b, cache_len=S + 2)
    assert l_quant.shape == l_dense.shape
    # fp8 per-channel: logits stay close to the dense model's
    rel = float(jnp.max(jnp.abs(l_quant - l_dense))
                / (jnp.max(jnp.abs(l_dense)) + 1e-9))
    assert rel < 0.25, rel
    tok = jnp.argmax(l_quant, -1)[:, None]
    l2, _ = model.decode_step(qparams, tok, cache)
    assert np.isfinite(np.asarray(l2, np.float32)).all()
