"""Equivalence tests for the §Perf optimizations (EXPERIMENTS.md):
GQA repeat-sharding, fp8 KV cache, fused-search kernel integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.configs import get_arch, reduced
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    runtime.flags["force_kv_repeat"] = 0
    runtime.flags["kv_cache_dtype"] = "bfloat16"


def _pair(cfg, model, params, S=20):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 1), 0,
                              cfg.vocab_size)
    l1, c1 = model.prefill(params, {"tokens": toks[:, :S]}, cache_len=S + 4)
    d1, _ = model.decode_step(params, toks[:, S:S + 1], c1)
    loss, _ = model.loss_fn(params, {"tokens": toks[:, :-1],
                                     "labels": toks[:, 1:]})
    return l1, d1, loss, c1


def test_kv_repeat_bit_exact():
    """Repeat-sharded caches/attention are numerically identical."""
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    l1, d1, loss1, c1 = _pair(cfg, model, params)

    runtime.flags["force_kv_repeat"] = 2
    model2 = build_model(cfg)
    l2, d2, loss2, c2 = _pair(cfg, model2, params)

    assert c2["stack"]["L0"]["k"].shape[3] == 2 * c1["stack"]["L0"]["k"].shape[3]
    np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                  np.asarray(l2, np.float32))
    np.testing.assert_array_equal(np.asarray(d1, np.float32),
                                  np.asarray(d2, np.float32))
    np.testing.assert_array_equal(np.asarray(loss1), np.asarray(loss2))


def test_fp8_kv_cache_close():
    """fp8 KV cache: decode logits within E4M3 noise of bf16 cache."""
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    _, d1, _, c1 = _pair(cfg, model, params)

    runtime.flags["kv_cache_dtype"] = "float8_e4m3fn"
    model2 = build_model(cfg)
    _, d2, _, c2 = _pair(cfg, model2, params)

    assert c2["stack"]["L0"]["k"].dtype == jnp.float8_e4m3fn
    rel = float(jnp.max(jnp.abs(d1 - d2)) / (jnp.max(jnp.abs(d1)) + 1e-9))
    assert rel < 0.15, rel


def test_fp8_kv_cache_ring():
    """fp8 cache + SWA ring compose."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_arch("mixtral-8x22b")),
                              capacity_factor=8.0)
    runtime.flags["kv_cache_dtype"] = "float8_e4m3fn"
    model = build_model(cfg)
    params = model.init(KEY)
    W = cfg.sliding_window
    toks = jax.random.randint(KEY, (2, W + 6), 0, cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :W + 5]},
                             cache_len=W)
    logits, _ = model.decode_step(params, toks[:, -1:], cache)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_quantized_abstract_specs_match_concrete():
    """quantized_param_specs layout == quantize_tree storage layout."""
    from repro.configs import QuantConfig
    from repro.core.daq import quantize_tree
    from repro.launch.specs import quantized_param_specs
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    base = jax.tree.map(lambda p: p * 0.99 if p.ndim >= 2 else p, params)
    q = QuantConfig()
    concrete, _ = quantize_tree(params, base, q, mode="storage")
    abstract = quantized_param_specs(
        jax.eval_shape(model.init, KEY), q)
    ca = jax.tree_util.tree_flatten_with_path(
        jax.tree.map(lambda x: (x.shape, str(x.dtype)), concrete))[0]
    ab = jax.tree_util.tree_flatten_with_path(
        jax.tree.map(lambda x: (x.shape, str(x.dtype)), abstract))[0]
    assert len(ca) == len(ab)
    for (pa, va), (pb, vb) in zip(ca, ab):
        assert va == vb, (pa, va, vb)
