"""Registry parity: repro.quantize.quantize vs the legacy entry points.

* ``method="daq"`` must reproduce legacy ``quantize_tree`` outputs (alpha,
  dequantized weights, global metrics) bit-exactly across granularities.
* ``method="absmax"`` must collapse *every* search knob (incl. the fused
  kernel sweep and per-block alpha) to a plain alpha=1 baseline.
* ``"smoothquant"`` / ``"awq"`` through the registry must match the study
  script's original equalization math on a small tree.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_arch, reduced
from repro.core.formats import get_format
from repro.core.granularity import absmax_scale, apply_qdq
from repro.quantize import available_methods, get_method, quantize

KEY = jax.random.PRNGKey(0)


def _pair_tree(seed=0, delta=0.002):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    post = {"blk": {"w": jax.random.normal(k1, (48, 64)) * 0.05,
                    "stack": jax.random.normal(k2, (3, 32, 48)) * 0.05},
            "norm_w": jnp.ones((48,))}
    base = jax.tree.map(
        lambda p: p - delta * jax.random.normal(KEY, p.shape)
        if p.ndim >= 2 else p, post)
    return post, base


def _legacy(fn_name, *args, **kw):
    from repro.core import daq
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return getattr(daq, fn_name)(*args, **kw)


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_methods():
    methods = available_methods()
    for m in ("absmax", "daq", "daq-per-block", "smoothquant", "awq"):
        assert m in methods
    with pytest.raises(KeyError, match="unknown quantization method"):
        get_method("nope")


def test_method_resolution_config_vs_override():
    post, base = _pair_tree()
    q = QuantConfig(method="daq", metric="sign", granularity="channel")
    # explicit method= overrides qcfg.method
    _, rep = quantize(post, base, q, method="absmax")
    assert rep.method == "absmax"
    for leaf in rep.per_leaf.values():
        assert np.all(np.asarray(leaf["alpha"]) == 1.0)
    # qcfg.method alone selects the algorithm
    _, rep2 = quantize(post, base, dataclasses.replace(q, method="absmax"))
    assert rep2.global_chosen == rep.global_chosen


# ---------------------------------------------------------------------------
# DAQ parity with the legacy tree walk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gran", ["tensor", "channel", "block"])
def test_daq_matches_legacy_quantize_tree(gran):
    post, base = _pair_tree()
    q = QuantConfig(metric="sign", granularity=gran, block_size=32,
                    alpha_min=0.8, alpha_max=1.25)
    new_tree, new_rep = quantize(post, base, q, method="daq")
    old_tree, old_rep = _legacy("quantize_tree", post, base, q)
    assert new_rep.global_chosen == old_rep.global_chosen
    assert new_rep.global_default == old_rep.global_default
    assert new_rep.n_quantized == old_rep.n_quantized
    assert new_rep.n_skipped == old_rep.n_skipped
    for name in old_rep.per_leaf:
        np.testing.assert_array_equal(np.asarray(new_rep.per_leaf[name]["alpha"]),
                                      np.asarray(old_rep.per_leaf[name]["alpha"]))
    for a, b in zip(jax.tree.leaves(new_tree), jax.tree.leaves(old_tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_daq_walk_matches_handrolled_reference():
    """The walk itself (skip policy, partial-sum aggregation, emission) is
    pinned against an independent hand-rolled implementation — the legacy
    quantize_tree is now a shim over the code under test, so shim-parity
    alone can't catch porting bugs in the walk."""
    from repro.core import metrics as M
    from repro.core.policy import path_str, should_quantize
    from repro.core.search import search_scale

    post, base = _pair_tree()
    q = QuantConfig(metric="cosine", granularity="block", block_size=32)
    got_tree, got_rep = quantize(post, base, q, method="daq")

    keys = ("sq_err", "n_sign_match", "dot", "dp_sq", "dq_sq", "count")
    agg_c = {k: 0.0 for k in keys}
    agg_d = {k: 0.0 for k in keys}
    exp_leaves, n_q, n_skip = {}, 0, 0
    flat, _ = jax.tree_util.tree_flatten_with_path(post)
    base_leaves = jax.tree_util.tree_leaves(base)
    for (path, wp), wb in zip(flat, base_leaves):
        name = path_str(path)
        if not should_quantize(name, wp, q.skip_patterns):
            n_skip += 1
            exp_leaves[name] = wp
            continue
        n_q += 1
        if wp.ndim == 2:
            res = search_scale(wp, wb, q)
        else:
            res = jax.vmap(lambda p, b: search_scale(p, b, q))(wp, wb)
        exp_leaves[name] = res.w_dq.astype(jnp.float32)
        for k in keys:
            agg_c[k] += float(jnp.sum(res.chosen[k]))
            agg_d[k] += float(jnp.sum(res.default[k]))
    exp_chosen = {k: float(v) for k, v in M.metrics_from_partials(
        {k: jnp.asarray(v) for k, v in agg_c.items()}).items()}
    exp_default = {k: float(v) for k, v in M.metrics_from_partials(
        {k: jnp.asarray(v) for k, v in agg_d.items()}).items()}

    assert got_rep.n_quantized == n_q and got_rep.n_skipped == n_skip
    np.testing.assert_allclose(
        [got_rep.global_chosen[k] for k in sorted(exp_chosen)],
        [exp_chosen[k] for k in sorted(exp_chosen)], rtol=1e-6)
    np.testing.assert_allclose(
        [got_rep.global_default[k] for k in sorted(exp_default)],
        [exp_default[k] for k in sorted(exp_default)], rtol=1e-6)
    got_flat, _ = jax.tree_util.tree_flatten_with_path(got_tree)
    for path, leaf in got_flat:
        np.testing.assert_array_equal(np.asarray(leaf, np.float32),
                                      np.asarray(exp_leaves[path_str(path)],
                                                 np.float32))


def test_daq_storage_matches_legacy():
    post, base = _pair_tree()
    q = QuantConfig(metric="cosine", granularity="block", block_size=32)
    new_tree, _ = quantize(post, base, q, mode="storage",
                           out_dtype="bfloat16")
    old_tree, _ = _legacy("quantize_tree", post, base, q, mode="storage",
                          out_dtype="bfloat16")
    node_new = new_tree["blk"]["w"]
    node_old = old_tree["blk"]["w"]
    np.testing.assert_array_equal(np.asarray(node_new.data, np.float32),
                                  np.asarray(node_old.data, np.float32))
    np.testing.assert_array_equal(np.asarray(node_new.scale),
                                  np.asarray(node_old.scale))
    assert node_new.eq_scale is None


# ---------------------------------------------------------------------------
# AbsMax collapses ALL search knobs
# ---------------------------------------------------------------------------

def test_absmax_clears_fused_kernel_and_per_block():
    """A caller with fused-sweep / per-block flags set must still get a
    plain alpha=1 AbsMax baseline (regression: the legacy absmax_tree left
    use_fused_kernel on, running a fused sweep inside the baseline)."""
    post, base = _pair_tree()
    hot = QuantConfig(granularity="block", block_size=32, metric="sign",
                      use_fused_kernel=True, per_block_alpha=True)
    plain = QuantConfig(granularity="block", block_size=32, metric="sign")
    t_hot, r_hot = quantize(post, base, hot, method="absmax")
    t_plain, r_plain = quantize(post, base, plain, method="absmax")
    assert r_hot.global_chosen == r_plain.global_chosen
    for name, leaf in r_hot.per_leaf.items():
        assert np.all(np.asarray(leaf["alpha"]) == 1.0), name
    for a, b in zip(jax.tree.leaves(t_hot), jax.tree.leaves(t_plain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # chosen == default: there was no search
    assert r_hot.global_chosen == r_hot.global_default


def test_absmax_tree_shim_matches_registry():
    post, base = _pair_tree()
    q = QuantConfig(granularity="channel", use_fused_kernel=True)
    _, r_new = quantize(post, base, q, method="absmax")
    _, r_old = _legacy("absmax_tree", post, base, q)
    assert r_new.global_chosen == r_old.global_chosen


def test_legacy_shims_warn():
    post, base = _pair_tree()
    q = QuantConfig(granularity="channel")
    from repro.core.daq import quantize_tree
    with pytest.warns(DeprecationWarning):
        quantize_tree(post, base, q)


# ---------------------------------------------------------------------------
# SmoothQuant / AWQ parity with the original study-script math
# ---------------------------------------------------------------------------

def _ref_equalize_2d(w2d, qcfg, mode, amax=None):
    """The study script's original per-leaf math (pre-registry), verbatim."""
    fmt = get_format(qcfg.fmt)
    w2d = w2d.astype(jnp.float32)
    in_dim = w2d.shape[0]
    if amax is None:
        amax = jnp.ones((in_dim,), jnp.float32)
    a = jnp.maximum(amax.astype(jnp.float32), 1e-6)
    wmax = jnp.maximum(jnp.max(jnp.abs(w2d), axis=1), 1e-6)

    def qdq_scaled(s_vec):
        ws = w2d * s_vec[:, None]
        sc = absmax_scale(ws, qcfg.granularity, fmt, qcfg.block_size)
        return apply_qdq(ws, sc, qcfg.granularity, fmt,
                         qcfg.block_size) / s_vec[:, None]

    if mode == "smoothquant":
        s = jnp.sqrt(a) / jnp.sqrt(wmax)
    else:
        best, best_err = None, jnp.inf
        for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
            s_try = jnp.maximum(a ** alpha / wmax ** (1 - alpha), 1e-6)
            err = jnp.sum(((qdq_scaled(s_try) - w2d) * a[:, None]) ** 2)
            if best is None or float(err) < float(best_err):
                best, best_err = s_try, err
        s = best
    s = jnp.maximum(s / jnp.maximum(jnp.max(s), 1e-6), 1e-4)
    return qdq_scaled(s)


@pytest.mark.parametrize("mode", ["smoothquant", "awq"])
def test_equalized_methods_match_study_reference(mode):
    post, base = _pair_tree(seed=2)
    q = QuantConfig(method=mode, granularity="channel")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # unit-stats fallback warning
        tree, rep = quantize(post, base, q)
    ref_w = _ref_equalize_2d(post["blk"]["w"], q, mode)
    np.testing.assert_allclose(np.asarray(tree["blk"]["w"]),
                               np.asarray(ref_w), rtol=0, atol=1e-7)
    ref_stack = jnp.stack([_ref_equalize_2d(post["blk"]["stack"][t], q, mode)
                           for t in range(3)])
    np.testing.assert_allclose(np.asarray(tree["blk"]["stack"]),
                               np.asarray(ref_stack), rtol=0, atol=1e-7)
    # skip policy still applies
    assert rep.n_skipped >= 1
    assert np.array_equal(np.asarray(tree["norm_w"]),
                          np.asarray(post["norm_w"]))


@pytest.mark.parametrize("mode", ["smoothquant", "awq"])
def test_equalized_storage_dequant_agree(mode):
    post, base = _pair_tree(seed=3)
    q = QuantConfig(method=mode, granularity="channel")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        deq, _ = quantize(post, base, q, mode="dequant")
        sto, rep = quantize(post, base, q, mode="storage",
                            out_dtype="float32")
    node = sto["blk"]["stack"]
    assert node.eq_scale is not None and node.eq_scale.shape == (3, 32)
    np.testing.assert_allclose(np.asarray(node.dequantize()),
                               np.asarray(deq["blk"]["stack"]), atol=1e-6)
    assert rep.quantized_bytes < rep.original_bytes


def test_calibration_stats_match_by_weight_identity():
    """Same-shaped weights must each get THEIR OWN activation stats
    (regression: the old study script matched stats to leaves by a
    per-shape FIFO, scrambling wq/wo, gate/up, and stacked layers)."""
    from repro.quant_runtime.qlinear import weight_fingerprint
    k = jax.random.split(KEY, 4)
    w_a = jax.random.normal(k[0], (32, 32)) * 0.05
    w_b = jax.random.normal(k[1], (32, 32)) * 0.05         # same shape as a
    w_s = jax.random.normal(k[2], (2, 32, 32)) * 0.05      # stacked
    post = {"a": w_a, "b": w_b, "s": w_s}
    base = jax.tree.map(lambda p: p * 0.99, post)
    amax = {"a": jnp.full((32,), 4.0), "b": jnp.full((32,), 0.25),
            "s0": jnp.linspace(0.5, 2.0, 32), "s1": jnp.linspace(2.0, 0.5, 32)}
    calib = [((32, 32), weight_fingerprint(w_a), amax["a"]),
             ((32, 32), weight_fingerprint(w_b), amax["b"]),
             ((32, 32), weight_fingerprint(w_s[0]), amax["s0"]),
             ((32, 32), weight_fingerprint(w_s[1]), amax["s1"])]
    q = QuantConfig(method="smoothquant", granularity="channel")
    _, rep = quantize(post, base, q, calib=calib)

    def expected_s(w2d, a):
        wmax = jnp.maximum(jnp.max(jnp.abs(w2d), axis=1), 1e-6)
        s = jnp.sqrt(jnp.maximum(a, 1e-6)) / jnp.sqrt(wmax)
        return jnp.maximum(s / jnp.maximum(jnp.max(s), 1e-6), 1e-4)

    np.testing.assert_allclose(np.asarray(rep.per_leaf["a"]["alpha"]),
                               np.asarray(expected_s(w_a, amax["a"])),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(rep.per_leaf["b"]["alpha"]),
                               np.asarray(expected_s(w_b, amax["b"])),
                               atol=1e-6)
    # stacked leaf: slice t gets slice t's stats, not call-order leftovers
    got = np.asarray(rep.per_leaf["s"]["alpha"])
    np.testing.assert_allclose(got[0], np.asarray(expected_s(w_s[0], amax["s0"])),
                               atol=1e-6)
    np.testing.assert_allclose(got[1], np.asarray(expected_s(w_s[1], amax["s1"])),
                               atol=1e-6)


def test_equalized_calibration_through_model():
    """End-to-end: stats collected via the calibrate hook on a real model
    change the equalization (vs unit stats) and keep metrics finite."""
    from repro.data import LanguageSpec
    from repro.models import build_model
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    base = jax.tree.map(lambda p: p * 0.995 if p.ndim >= 2 else p, params)
    spec = LanguageSpec(vocab=cfg.vocab_size)
    q = QuantConfig(method="smoothquant", granularity="channel")
    with warnings.catch_warnings():
        # a properly calibrated run must not cry wolf (embed tables never
        # route through matmul and are exempt from the miss warning)
        warnings.simplefilter("error", UserWarning)
        calibrated, rep_c = quantize(params, base, q, model=model, spec=spec,
                                     calib_batches=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        unit, rep_u = quantize(params, base, q)
    assert rep_c.n_quantized == rep_u.n_quantized > 0
    for v in rep_c.global_chosen.values():
        assert np.isfinite(v)
    # real activation stats must actually steer s away from the unit case
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in
             zip(jax.tree.leaves(calibrated), jax.tree.leaves(unit))]
    assert max(diffs) > 0


def test_quantize_defaults_base_to_post():
    post, _ = _pair_tree()
    _, rep = quantize(post, qcfg=QuantConfig(granularity="channel"))
    # base defaults to post: zero delta, reconstruction-only regime.
    # delta_l2 reduces to the plain quantization error and stays finite.
    assert rep.n_quantized > 0
    for v in rep.global_chosen.values():
        assert np.isfinite(v)
    assert rep.global_chosen["mse"] > 0


def test_quantize_rejects_bad_mode():
    post, base = _pair_tree()
    with pytest.raises(ValueError, match="mode"):
        quantize(post, base, QuantConfig(), mode="nope")


def test_calibration_requires_model_and_spec_together():
    post, base = _pair_tree()
    q = QuantConfig(method="smoothquant", granularity="channel")
    with pytest.raises(ValueError, match="BOTH model= and spec="):
        quantize(post, base, q, model=object())
    with pytest.raises(ValueError, match="BOTH model= and spec="):
        quantize(post, base, q, spec=object())


def test_empty_calib_warns_like_none():
    post, base = _pair_tree()
    q = QuantConfig(method="smoothquant", granularity="channel")
    with pytest.warns(UserWarning, match="no calibration stats"):
        quantize(post, base, q, calib=[])


def test_calibration_miss_warns_once():
    """Stats present but a leaf unmatched -> one loud warning, not silent
    unit-scale degradation."""
    from repro.quant_runtime.qlinear import weight_fingerprint
    post, base = _pair_tree()
    other = jax.random.normal(KEY, (48, 64))  # fingerprint matches nothing
    calib = [((48, 64), weight_fingerprint(other), jnp.ones((48,)))]
    q = QuantConfig(method="smoothquant", granularity="channel")
    with pytest.warns(UserWarning, match="no calibration record matches"):
        quantize(post, base, q, calib=calib)
