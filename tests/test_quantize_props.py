"""Property sweep over the quantize registry: every registered method x
storage/dequant mode x out_dtype must round-trip (``mode="storage"`` nodes
dequantize to exactly what ``mode="dequant"`` emits), respect the skip
policy (norms / biases / 1-D leaves untouched, bit-for-bit), and report
consistent byte accounting."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import QuantConfig
from repro.quant_runtime.qparams import QuantizedTensor
from repro.quantize import available_methods, quantize

KEY = jax.random.PRNGKey(0)


def _pair_tree(seed=0, delta=0.002):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    post = {"blk": {"w": jax.random.normal(k1, (48, 64)) * 0.05,
                    "stack": jax.random.normal(k2, (3, 32, 48)) * 0.05},
            "norm_scale": jnp.ones((48,)),
            "bias_q": jnp.zeros((16,))}
    base = jax.tree.map(
        lambda p: p - delta * jax.random.normal(KEY, p.shape)
        if p.ndim >= 2 else p, post)
    return post, base


def _quantize_quiet(*args, **kw):
    with warnings.catch_warnings():
        # calibration-based methods fall back to unit activation scales
        # (with a warning) when no calib data is passed — that fallback is
        # exactly the configuration under test here
        warnings.simplefilter("ignore")
        return quantize(*args, **kw)


_METHODS = available_methods()
_DTYPES = ("float32", "bfloat16")
_GRANS = ("tensor", "channel", "block")


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(_METHODS), st.sampled_from(_DTYPES),
       st.sampled_from(_GRANS), st.integers(min_value=0, max_value=10**6))
def test_storage_roundtrips_dequant_for_every_method(method, dtype, gran,
                                                     seed):
    """For any (method, dtype, granularity, weights): the storage-mode
    QuantizedTensor nodes dequantize to the dequant-mode emission within
    the cast tolerance of ``out_dtype``, and both modes agree on alphas,
    skip counts and global metrics."""
    post, base = _pair_tree(seed % 13)
    q = QuantConfig(method=method, granularity=gran, block_size=16,
                    metric="sign", alpha_min=0.8, alpha_max=1.25)
    deq_tree, deq_rep = _quantize_quiet(post, base, q, mode="dequant",
                                        out_dtype=dtype)
    sto_tree, sto_rep = _quantize_quiet(post, base, q, mode="storage",
                                        out_dtype=dtype)
    assert deq_rep.method == sto_rep.method == method
    assert deq_rep.n_quantized == sto_rep.n_quantized > 0
    assert deq_rep.n_skipped == sto_rep.n_skipped
    assert deq_rep.global_chosen == sto_rep.global_chosen

    deq_flat = {"/".join(str(getattr(k, "key", k)) for k in p): l
                for p, l in jax.tree_util.tree_flatten_with_path(deq_tree)[0]}
    # storage tree: QuantizedTensor is a pytree node, walk dict manually
    def walk(node, prefix=""):
        if isinstance(node, dict):
            for k, v in node.items():
                yield from walk(v, f"{prefix}{k}/")
        else:
            yield prefix.rstrip("/"), node

    n_qt = 0
    atol = 1e-6 if dtype == "float32" else 1e-2   # bf16 cast tolerance
    for name, leaf in walk(sto_tree):
        ref = deq_flat[name]
        if isinstance(leaf, QuantizedTensor):
            n_qt += 1
            got = leaf.dequantize()
            assert got.dtype == jnp.dtype(dtype)
            assert got.shape == ref.shape
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(ref, np.float32),
                atol=atol, rtol=0,
                err_msg=f"{method}/{gran}/{dtype}: {name}")
        else:
            # skip-policy leaf: untouched, bit for bit, original dtype
            assert leaf.dtype == ref.dtype
            np.testing.assert_array_equal(np.asarray(leaf), np.asarray(ref))
    assert n_qt == sto_rep.n_quantized
    # storage really is smaller than the original float tree
    assert sto_rep.quantized_bytes < sto_rep.original_bytes


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(_METHODS), st.integers(min_value=0, max_value=10**6))
def test_skip_policy_leaves_identical_objects(method, seed):
    """Skipped leaves are passed through the walk unchanged — the same
    values land in the output tree for every method and mode."""
    post, base = _pair_tree(seed % 7)
    q = QuantConfig(method=method, granularity="channel")
    for mode in ("dequant", "storage"):
        tree, rep = _quantize_quiet(post, base, q, mode=mode)
        assert rep.n_skipped == 2                  # norm_scale + bias_q
        np.testing.assert_array_equal(np.asarray(tree["norm_scale"]),
                                      np.asarray(post["norm_scale"]))
        np.testing.assert_array_equal(np.asarray(tree["bias_q"]),
                                      np.asarray(post["bias_q"]))
        assert not isinstance(tree["norm_scale"], QuantizedTensor)
        assert not isinstance(tree["bias_q"], QuantizedTensor)


def test_dequantize_error_within_format_tolerance():
    """Absolute reconstruction sanity for every method: fp8_e4m3 block
    quantization reconstructs small-magnitude gaussian weights to a few
    percent relative error — catches methods whose storage emission and
    dequantize() disagree about scale layout."""
    post, base = _pair_tree(3)
    w = np.asarray(post["blk"]["w"], np.float32)
    for method in _METHODS:
        q = QuantConfig(method=method, granularity="block", block_size=16)
        tree, _ = _quantize_quiet(post, base, q, mode="storage",
                                  out_dtype="float32")
        got = np.asarray(tree["blk"]["w"].dequantize(), np.float32)
        rel = np.abs(got - w).mean() / np.abs(w).mean()
        assert rel < 0.1, (method, rel)
