"""Pin the roofline arithmetic (analysis/roofline.py): term math,
dominant-term selection, MFU, and the 6*N*D model-FLOPs estimate."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     model_flops_estimate,
                                     roofline_from_costs)


def test_terms_normalize_to_one_second():
    r = roofline_from_costs(PEAK_FLOPS, HBM_BW, ICI_BW,
                            model_flops=PEAK_FLOPS, n_chips=1)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.step_time_s == pytest.approx(1.0)


@pytest.mark.parametrize("flops,bytes_,coll,want", [
    (2 * PEAK_FLOPS, HBM_BW, ICI_BW, "compute"),
    (PEAK_FLOPS, 3 * HBM_BW, ICI_BW, "memory"),
    (PEAK_FLOPS, HBM_BW, 5 * ICI_BW, "collective"),
])
def test_dominant_term(flops, bytes_, coll, want):
    r = roofline_from_costs(flops, bytes_, coll, model_flops=1.0, n_chips=1)
    assert r.dominant == want
    assert r.step_time_s == pytest.approx(
        max(r.compute_s, r.memory_s, r.collective_s))


def test_mfu_and_useful_fraction():
    # 2 chips, each compiled at exactly half peak for 1s; the model math
    # accounts for half the compiled FLOPs
    flops_per_chip = PEAK_FLOPS / 2
    model = flops_per_chip  # = half of the 2-chip compiled total
    r = roofline_from_costs(flops_per_chip, 0.0, 0.0, model_flops=model,
                            n_chips=2)
    assert r.useful_flops_frac == pytest.approx(0.5)
    # bound step time = 0.5s; mfu = (peak/2) / (0.5s * 2 chips * peak)
    assert r.mfu == pytest.approx(0.5)


def test_mfu_zero_guards():
    r = roofline_from_costs(0.0, 0.0, 0.0, model_flops=0.0, n_chips=1)
    assert r.mfu == 0.0
    assert r.useful_flops_frac == 0.0


def test_row_is_json_shaped():
    r = roofline_from_costs(PEAK_FLOPS, HBM_BW, 0.0, model_flops=1e9,
                            n_chips=4)
    row = r.row()
    assert row["dominant"] in ("compute", "memory", "collective")
    for k in ("compute_s", "memory_s", "collective_s", "model_flops",
              "hlo_flops_per_chip", "useful_flops_frac", "mfu_bound"):
        assert k in row


# -- model_flops_estimate ---------------------------------------------------

def _tree(dense=1000, moe=0, embed=500):
    t = {"embed": {"w": np.zeros((embed,))},
         "stack": {"l0": {"attn": {"wq": np.zeros((dense,))}}}}
    if moe:
        t["stack"]["l0"]["moe"] = {"experts": np.zeros((moe,)),
                                   "router": {"w": np.zeros((7,))}}
    return t


def test_model_flops_dense_modes():
    cfg = SimpleNamespace(n_experts=0, top_k=0)
    shape = SimpleNamespace(global_batch=4, seq_len=16)
    tree = _tree(dense=1000)
    # embedding excluded; N = 1000
    assert model_flops_estimate(cfg, tree, shape, mode="train") \
        == pytest.approx(6.0 * 1000 * 4 * 16)
    assert model_flops_estimate(cfg, tree, shape, mode="prefill") \
        == pytest.approx(2.0 * 1000 * 4 * 16)
    assert model_flops_estimate(cfg, tree, shape, mode="decode") \
        == pytest.approx(2.0 * 1000 * 4)


def test_model_flops_moe_active_fraction():
    cfg = SimpleNamespace(n_experts=8, top_k=2)
    shape = SimpleNamespace(global_batch=1, seq_len=1)
    tree = _tree(dense=1000, moe=800)
    # router (7 params) counts as dense/active; expert params scale by
    # top_k / n_experts
    active = (1000 + 7) + 800 * 2 / 8
    assert model_flops_estimate(cfg, tree, shape, mode="decode") \
        == pytest.approx(2.0 * active)


def test_model_flops_head_and_embed_excluded():
    cfg = SimpleNamespace(n_experts=0, top_k=0)
    shape = SimpleNamespace(global_batch=1, seq_len=1)
    tree = _tree(dense=1000)
    tree["w_head"] = np.zeros((12345,))
    base = model_flops_estimate(cfg, _tree(dense=1000), shape, mode="decode")
    assert model_flops_estimate(cfg, tree, shape, mode="decode") \
        == pytest.approx(base)
