"""Algorithm 1 invariants + fused/per-block variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import QuantConfig
from repro.core import metrics as M
from repro.core.formats import get_format
from repro.core.granularity import absmax_scale, apply_qdq
from repro.core.search import search_scale


def _pair(seed, shape=(96, 64), delta=0.003):
    key = jax.random.PRNGKey(seed)
    wb = jax.random.normal(key, shape) * 0.05
    wp = wb + jax.random.normal(jax.random.PRNGKey(seed + 1), shape) * delta
    return wp, wb


@pytest.mark.parametrize("metric", ["mse", "sign", "cosine", "hybrid"])
@pytest.mark.parametrize("gran", ["tensor", "channel", "block"])
def test_never_worse_than_absmax(metric, gran):
    """Alg.1 lines 4-6: alpha=1 is the incumbent, so the chosen scale is
    never worse than AbsMax on the chosen metric."""
    wp, wb = _pair(0)
    q = QuantConfig(metric=metric, granularity=gran, block_size=32)
    res = search_scale(wp, wb, q)
    fmt = get_format(q.fmt)
    s0 = absmax_scale(wp, gran, fmt, 32)
    dp = wp - wb
    dq0 = apply_qdq(wp, s0, gran, fmt, 32) - wb
    m_abs = float(M.objective(metric, dp, dq0))
    m_chosen = float(M.objective(metric, dp, res.w_dq - wb))
    assert m_chosen >= m_abs - 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_alpha_within_range(seed):
    wp, wb = _pair(seed)
    q = QuantConfig(metric="cosine", granularity="block", block_size=32,
                    alpha_min=0.8, alpha_max=1.25)
    res = search_scale(wp, wb, q)
    a = float(res.alpha)
    assert 0.8 - 1e-6 <= a <= 1.25 + 1e-6 or abs(a - 1.0) < 1e-6


@pytest.mark.parametrize("metric", ["mse", "sign", "cosine"])
def test_fused_kernel_matches_naive(metric):
    wp, wb = _pair(3, shape=(256, 128))
    q1 = QuantConfig(metric=metric, granularity="block", block_size=128)
    q2 = dataclasses.replace(q1, use_fused_kernel=True)
    r1 = search_scale(wp, wb, q1)
    r2 = search_scale(wp, wb, q2)
    assert abs(float(r1.alpha) - float(r2.alpha)) < 1e-6
    np.testing.assert_allclose(np.asarray(r1.w_dq), np.asarray(r2.w_dq))


@pytest.mark.parametrize("metric", ["mse", "sign"])
def test_per_block_at_least_as_good(metric):
    """Separable metrics: per-block alpha beats any shared alpha on the
    same candidate grid (beyond-paper extension)."""
    wp, wb = _pair(4, shape=(128, 96))
    q_shared = QuantConfig(metric=metric, granularity="block", block_size=32)
    q_block = dataclasses.replace(q_shared, per_block_alpha=True)
    r_s = search_scale(wp, wb, q_shared)
    r_b = search_scale(wp, wb, q_block)
    dp = wp - wb
    m_s = float(M.objective(metric, dp, r_s.w_dq - wb))
    m_b = float(M.objective(metric, dp, r_b.w_dq - wb))
    assert m_b >= m_s - 1e-6


def test_stacked_leaves_vmapped():
    """[L, I, O] weights get one alpha per layer (Alg. 1 per-layer loop)."""
    from repro.core.daq import quantize_tree
    wp, wb = _pair(5, shape=(3, 64, 48))
    q = QuantConfig(metric="sign", granularity="channel")
    out, report = quantize_tree({"w": wp}, {"w": wb}, q)
    assert np.asarray(report.per_leaf["w"]["alpha"]).shape == (3,)


def test_zero_delta_perfect_metrics():
    """W_post == W_base: cosine undefined-but-safe, sign counts zeros."""
    wb = jax.random.normal(jax.random.PRNGKey(7), (64, 64)) * 0.1
    q = QuantConfig(metric="sign", granularity="channel")
    res = search_scale(wb, wb, q)
    assert np.isfinite(float(res.chosen["cosine"]))
