"""Self-test for repro.staticcheck: the seeded-violation fixture must make
the checker fail, the clean tree must pass, the baseline ratchet must only
go down, and the compile contracts must catch planted hazards."""
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.staticcheck.contracts import (check_entry, signature_fingerprint,
                                         weak_type_leaves)
from repro.staticcheck.lint import lint_file, lint_tree
from repro.staticcheck.report import (Violation, diff_baseline,
                                      load_baseline, write_baseline)

REPO = Path(__file__).resolve().parent.parent
FIXTURE = Path(__file__).resolve().parent / "fixtures" / "staticcheck_bad"


# -- lint pass: seeded fixture must fail ------------------------------------

def test_fixture_seeds_every_lint_rule():
    vs, n_files = lint_tree(FIXTURE)
    assert n_files == 1
    rules = sorted(v.rule for v in vs)
    # step_body: 2 host syncs + 1 list literal; undonated jit call +
    # undonated decorated def
    assert rules == ["host-sync", "host-sync", "list-asarray",
                     "undonated-jit", "undonated-jit"]
    # and the checker would fail: against an empty baseline all are new
    new, waived, stale = diff_baseline(vs, {})
    assert len(new) == len(vs) and not waived and not stale


def test_fixture_pragmas_suppress():
    vs = lint_file(FIXTURE / "engine" / "scheduler.py",
                   "engine/scheduler.py")
    symbols = {(v.rule, v.symbol) for v in vs}
    # the ok[host-sync] pragma and the host-boundary decorator comment
    # keep allowed_body/drain out; the donated variants never fire
    assert ("host-sync", "allowed_body") not in symbols
    assert ("host-sync", "drain") not in symbols
    assert ("undonated-jit", "decorated_ok") not in symbols
    assert ("undonated-jit", "decorated_update") in symbols


def test_fixture_outside_traced_scope_only_flags_jit():
    # the same source under a host-side path: host-sync/list-asarray are
    # fine there, the undonated jits are hazards anywhere
    vs = lint_file(FIXTURE / "engine" / "scheduler.py", "launch/serve.py")
    assert sorted(v.rule for v in vs) == ["undonated-jit", "undonated-jit"]


def test_real_tree_is_clean():
    vs, n_files = lint_tree(REPO / "src" / "repro")
    assert n_files > 50
    assert vs == [], [v.key for v in vs]


# -- baseline ratchet -------------------------------------------------------

def test_baseline_roundtrip_and_ratchet(tmp_path):
    a = Violation(kind="lint", rule="host-sync", where="engine/x.py",
                  symbol="f", msg="m", line=3)
    b = Violation(kind="contract", rule="donation-not-landed",
                  where="case/_dispatch", symbol="arg[2]/k", msg="m",
                  bytes_wasted=4096)
    path = tmp_path / "baseline.json"
    write_baseline(path, [a])
    waivers = load_baseline(path)
    assert set(waivers) == {a.key}

    # waived violation passes; a new one fails; fixing `a` leaves a stale
    # waiver (ratchet surface to drop via --update)
    new, waived, stale = diff_baseline([a, b], waivers)
    assert [v.key for v in new] == [b.key]
    assert [v.key for v in waived] == [a.key]
    new, waived, stale = diff_baseline([], waivers)
    assert not new and not waived and stale == [a.key]


def test_baseline_missing_and_bad_version(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == {}
    (tmp_path / "bad.json").write_text('{"version": 99, "waivers": []}')
    with pytest.raises(ValueError):
        load_baseline(tmp_path / "bad.json")


def test_checked_in_baseline_has_no_engine_waivers():
    """PR acceptance: no waiver may hide a host-sync or donation violation
    in a decode dispatch."""
    waivers = load_baseline(REPO / "staticcheck_baseline.json")
    for key in waivers:
        assert not (("host" in key or "donation" in key)
                    and "_dispatch" in key), key


# -- compile contracts on planted hazards -----------------------------------

def _rec(fn, donate=(), statics=()):
    return {"fn": jax.jit(fn, donate_argnums=donate,
                          static_argnums=statics),
            "donate": donate, "static_argnums": statics,
            "cache_arg": None, "cache_out": None}


def _check(rec, args, **kw):
    kw.setdefault("expect", None)
    kw.setdefault("update", True)
    return check_entry("self", "entry", rec, args, **kw)


def test_contract_catches_unlanded_donation():
    # the donated (64,64) buffer cannot alias the scalar output
    cache = jnp.zeros((64, 64))
    res = _check(_rec(lambda cache, x: jnp.sum(cache) + x, donate=(0,)),
                 (cache, jnp.float32(1.0)))
    rules = [v.rule for v in res.violations]
    assert rules == ["donation-not-landed"]
    assert res.violations[0].bytes_wasted == 64 * 64 * 4


def test_contract_accepts_landed_donation():
    cache = jnp.zeros((64, 64))
    res = _check(_rec(lambda cache, x: cache.at[0, 0].set(x), donate=(0,)),
                 (cache, jnp.float32(1.0)))
    assert res.violations == []


def test_contract_catches_host_callback():
    def f(x):
        jax.debug.print("x={}", jnp.sum(x))
        return x * 2
    res = _check(_rec(f), (jnp.zeros((8, 8)),))
    assert [v.rule for v in res.violations] == ["host-boundary"]


def test_contract_catches_weak_type_and_fingerprints_drift():
    f = lambda x, y: x + y
    args_weak = (jnp.zeros((4,)), 1.0)       # python float: weak leaf
    assert weak_type_leaves(args_weak, ()) == ["arg[1]/"]
    res = _check(_rec(f), args_weak)
    assert "weak-type-signature" in [v.rule for v in res.violations]

    args = (jnp.zeros((4,)), jnp.float32(1.0))
    fp = signature_fingerprint(args, ())
    assert fp == signature_fingerprint(args, ())          # deterministic
    assert fp != signature_fingerprint((jnp.zeros((5,)),
                                        jnp.float32(1.0)), ())
    res = _check(_rec(f), args, expect={"fingerprint": "0" * 16},
                 update=False)
    assert [v.rule for v in res.violations] == ["recompile-fingerprint"]
    res = _check(_rec(f), args, expect={"fingerprint": fp}, update=False)
    assert res.violations == []


def test_contract_missing_manifest_entry_fails_unless_update():
    f = lambda x: x * 2
    args = (jnp.zeros((4,)),)
    res = _check(_rec(f), args, expect=None, update=False)
    assert [v.rule for v in res.violations] == ["fingerprint-missing"]
    res = _check(_rec(f), args, expect=None, update=True)
    assert res.violations == []


def test_contract_catches_cache_dtype_drift():
    def f(cache, x):
        return {"k": cache["k"].astype(jnp.float32) + x}  # bf16 -> f32
    rec = _rec(f, donate=(0,))
    rec["cache_arg"], rec["cache_out"] = 0, 0
    cache = {"k": jnp.zeros((64, 64), jnp.bfloat16)}
    res = _check(rec, (cache, jnp.float32(1.0)),
                 cache_in=cache)
    assert "cache-dtype-drift" in [v.rule for v in res.violations]


def test_contract_clean_on_dtype_stable_cache():
    def f(cache, x):
        return {"k": (cache["k"] + x).astype(cache["k"].dtype)}
    rec = _rec(f, donate=(0,))
    rec["cache_arg"], rec["cache_out"] = 0, 0
    cache = {"k": jnp.zeros((64, 64), jnp.bfloat16)}
    res = _check(rec, (cache, jnp.bfloat16(1.0)), cache_in=cache)
    assert res.violations == []
