"""System/integration tests: quantize_tree end-to-end, distributed dry-run
(subprocess with fake devices), serving batcher."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import QuantConfig, get_arch, reduced
from repro.core.daq import absmax_tree, quantize_tree
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _pair_tree():
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    post = model.init(KEY)
    base = jax.tree.map(
        lambda p: p + (0.002 * jax.random.normal(KEY, p.shape)).astype(p.dtype)
        if p.ndim >= 2 else p, post)
    return cfg, model, post, base


def test_quantize_tree_skips_norms_and_1d():
    cfg, model, post, base = _pair_tree()
    _, report = quantize_tree(post, base, QuantConfig(granularity="channel"))
    assert report.n_skipped > 0
    for name in report.per_leaf:
        assert "norm" not in name and "bias" not in name


def test_storage_and_dequant_modes_agree():
    cfg, model, post, base = _pair_tree()
    q = QuantConfig(granularity="block", block_size=32, metric="sign")
    deq, _ = quantize_tree(post, base, q, mode="dequant")
    sto, r2 = quantize_tree(post, base, q, mode="storage",
                            out_dtype="float32")
    wq_deq = deq["stack"]["L0"]["attn"]["wq"]
    node = sto["stack"]["L0"]["attn"]["wq"]
    np.testing.assert_allclose(np.asarray(wq_deq, np.float32),
                               np.asarray(node.dequantize(), np.float32),
                               atol=1e-3)
    assert r2.quantized_bytes < r2.original_bytes


def test_daq_beats_absmax_on_its_metric():
    cfg, model, post, base = _pair_tree()
    q = QuantConfig(granularity="block", block_size=32, metric="sign",
                    alpha_min=0.5, alpha_max=2.0)
    _, r_daq = quantize_tree(post, base, q)
    _, r_abs = absmax_tree(post, base, q)
    assert (r_daq.global_chosen["sign_rate"]
            >= r_abs.global_chosen["sign_rate"] - 1e-6)


def test_eq7_mse_search_is_base_agnostic():
    """MSE metric ignores the base model (paper Eq. 7): same alpha with any
    base."""
    from repro.core.search import search_scale
    wp = jax.random.normal(KEY, (64, 64)) * 0.1
    wb1 = jnp.zeros_like(wp)
    wb2 = jax.random.normal(jax.random.PRNGKey(1), (64, 64)) * 0.1
    q = QuantConfig(metric="mse", granularity="channel",
                    alpha_min=0.5, alpha_max=2.0)
    a1 = float(search_scale(wp, wb1, q).alpha)
    a2 = float(search_scale(wp, wb2, q).alpha)
    assert abs(a1 - a2) < 1e-6


def test_mini_dryrun_subprocess():
    """The production dry-run machinery on an 8-device fake mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax
from repro.configs import TrainConfig, get_arch, reduced, ShapeConfig
from repro.launch import sharding as SH
from repro.launch.mesh import _auto, use_mesh
from repro.launch.steps import make_train_step
from repro.launch.specs import train_batch_specs, state_specs
from repro.models import build_model

cfg = reduced(get_arch("glm4-9b"))
model = build_model(cfg)
tc = TrainConfig()
mesh = jax.make_mesh((4, 2), ("data", "model"), **_auto(2))
state = state_specs(model, tc)
shape = ShapeConfig("mini", 64, 8, "train")
batch = train_batch_specs(cfg, shape)
st_sh = {"params": SH.params_shardings(state["params"], cfg, mesh),
         "opt": SH.opt_state_shardings(state["opt"], state["params"], cfg,
                                       mesh)}
b_sh = SH.batch_shardings(batch, mesh)
step = make_train_step(model, tc)
with use_mesh(mesh):
    compiled = jax.jit(step, in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None),
                       donate_argnums=0).lower(state, batch).compile()
print("COMPILED_OK", compiled.memory_analysis().temp_size_in_bytes)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=560)
    assert "COMPILED_OK" in r.stdout, r.stderr[-2000:]


def test_serving_batcher_outputs():
    """Continuous-batching serve(): all requests get gen_tokens tokens."""
    from repro.data import LanguageSpec, sample_batch
    from repro.launch.serve import serve
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    spec = LanguageSpec(vocab=cfg.vocab_size)
    prompts = [sample_batch(jax.random.PRNGKey(i), spec, 1, 12)[0]
               for i in range(3)]
    outs = serve(model, params, prompts, batch=2, gen_tokens=4, cache_len=24)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)


def test_serve_greedy_matches_plain_decode():
    """The slot batcher reproduces plain greedy decoding per request."""
    from repro.data import LanguageSpec, sample_batch
    from repro.launch.serve import serve
    cfg = reduced(get_arch("glm4-9b"))
    model = build_model(cfg)
    params = model.init(KEY)
    spec = LanguageSpec(vocab=cfg.vocab_size)
    prompt = sample_batch(jax.random.PRNGKey(3), spec, 1, 12)[0]
    outs = serve(model, params, [prompt], batch=2, gen_tokens=4,
                 cache_len=24)
    logits, cache = model.prefill(params, {"tokens": prompt[None]},
                                  cache_len=24)
    ref = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    ref.append(int(tok[0, 0]))
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        ref.append(int(tok[0, 0]))
    assert outs[0] == ref
