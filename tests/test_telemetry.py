"""Telemetry tests: histogram bucket math and closed-form percentiles,
metrics snapshot schema, Chrome-trace schema (monotonic per-track clocks),
and the zero-cost contract of the device counters — metrics on vs off must
produce identical outputs, identical host-sync counts and identical jit
cache sizes, because the host-side layers only *read* values the serve
loop already fetched.
"""
import json
import math

import jax
import pytest

from repro.configs import get_arch, reduced
from repro.data import LanguageSpec, sample_batch
from repro.engine import Engine
from repro.models import build_model
from repro.telemetry import (COUNTER_KEYS, METRICS_SCHEMA, Histogram,
                             MetricsRegistry, Tracer)
from repro.telemetry.metrics import log_bucket_edges

KEY = jax.random.PRNGKey(0)

_BUILT: dict = {}


def _setup(arch="glm4-9b"):
    if arch not in _BUILT:
        cfg = reduced(get_arch(arch))
        model = build_model(cfg)
        params = model.init(KEY)
        _BUILT[arch] = (cfg, model, params,
                        LanguageSpec(vocab=cfg.vocab_size))
    return _BUILT[arch]


# ---------------------------------------------------------------------------
# Histogram: bucket edges + percentiles (closed-form)
# ---------------------------------------------------------------------------

def test_log_bucket_edges_closed_form():
    lo, hi, n = 1e-3, 1e3, 6
    edges = log_bucket_edges(lo, hi, n)
    assert len(edges) == n + 1
    assert edges[0] == lo and edges[-1] == hi      # endpoints pinned exactly
    for i, e in enumerate(edges):
        assert e == pytest.approx(lo * (hi / lo) ** (i / n))
    ratios = [edges[i + 1] / edges[i] for i in range(n)]
    for r in ratios:                                # constant ratio
        assert r == pytest.approx((hi / lo) ** (1 / n))
    for bad in ((0.0, 1.0, 4), (2.0, 1.0, 4), (1.0, 1.0, 4)):
        with pytest.raises(ValueError):
            log_bucket_edges(*bad)
    with pytest.raises(ValueError):
        log_bucket_edges(1.0, 2.0, 0)


def test_histogram_bucket_membership():
    h = Histogram("t", lo=1.0, hi=100.0, n_buckets=4)
    # edges: 1, 100^(1/4)=3.162.., 10, 31.62.., 100
    for v in (0.5, 1.0, 3.0, 11.0, 99.0, 100.0, 250.0):
        h.observe(v)
    assert h.count == 7
    assert sum(h.bucket_counts) == h.count          # every sample bucketed
    assert h.bucket_counts == [1, 2, 0, 1, 1, 2]
    # every in-range sample sits inside its bucket's half-open interval
    for v in (1.0, 3.0, 11.0, 99.0):
        i = next(j for j in range(h.n_buckets)
                 if h.edges[j] <= v < h.edges[j + 1])
        assert h.edges[i] <= v < h.edges[i + 1]


def test_histogram_edge_values_never_misbucket():
    h = Histogram("e", lo=1e-4, hi=1e3, n_buckets=32)
    for i, e in enumerate(h.edges[:-1]):            # exact edge values
        h.observe(e)
        assert h.bucket_counts[1 + i] >= 1, f"edge {i} ({e}) misbucketed"
    assert sum(h.bucket_counts) == h.count


def test_percentiles_nearest_rank_closed_form():
    h = Histogram("p", lo=1e-3, hi=1e3)
    for v in range(1, 101):                          # 1..100
        h.observe(float(v))
    # nearest-rank over n=100: rank = ceil(q), value = rank
    assert h.percentile(50) == 50.0
    assert h.percentile(95) == 95.0
    assert h.percentile(99) == 99.0
    assert h.percentile(100) == 100.0
    assert h.percentile(0.5) == 1.0                  # rank floor at 1
    d = h.to_dict()
    assert d["count"] == 100
    assert d["min"] == 1.0 and d["max"] == 100.0
    assert d["sum"] == 5050.0
    assert (d["p50"], d["p95"], d["p99"]) == (50.0, 95.0, 99.0)
    # odd n: nearest-rank p50 of [1, 2, 3] is 2
    h3 = Histogram("q")
    for v in (3.0, 1.0, 2.0):
        h3.observe(v)
    assert h3.percentile(50) == 2.0
    assert math.ceil(50 / 100 * 3) == 2              # the rank formula


def test_percentile_empty_and_singleton():
    h = Histogram("s")
    assert h.percentile(50) is None
    assert "p50" not in h.to_dict()
    h.observe(2.5)
    assert h.percentile(50) == h.percentile(99) == 2.5


# ---------------------------------------------------------------------------
# Registry snapshot: stable schema, JSON round-trip
# ---------------------------------------------------------------------------

def test_registry_snapshot_schema(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2)                          # get-or-create
    reg.gauge("g").set(3.5)
    reg.gauge("unset")                               # stays None -> n/a
    reg.histogram("h", unit="s").observe(0.1)
    snap = reg.snapshot()
    assert METRICS_SCHEMA == "repro.telemetry.metrics/v1"
    assert snap["schema"] == METRICS_SCHEMA
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"g": 3.5, "unset": None}
    hd = snap["histograms"]["h"]
    assert hd["count"] == 1 and hd["unit"] == "s"
    assert len(hd["counts"]) == len(hd["edges"]) + 1  # under+overflow
    path = tmp_path / "metrics.json"
    reg.save(path)
    assert json.loads(path.read_text()) == snap      # plain JSON types only
    s = reg.summary()
    assert "unset: n/a" in s and "p50=" in s and "a: 3" in s


# ---------------------------------------------------------------------------
# Tracer: Chrome trace-event schema, monotonic per-track timestamps
# ---------------------------------------------------------------------------

def _check_chrome_trace(doc):
    """Schema assertions shared by the unit test and the serve test."""
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    per_track: dict = {}
    meta_tids = set()
    for ev in evs:
        assert {"name", "ph", "pid"} <= set(ev)
        if ev["ph"] == "M":
            if ev["name"] == "thread_name":
                meta_tids.add(ev["tid"])
            continue
        assert ev["ts"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
        if ev["ph"] == "i":
            assert ev["s"] in ("t", "p", "g")
        if ev["ph"] in ("X", "i"):
            per_track.setdefault(ev["tid"], []).append(ev["ts"])
    for tid, ts in per_track.items():
        assert ts == sorted(ts), f"track {tid} timestamps not monotonic"
    assert set(per_track) <= meta_tids, "track without thread_name metadata"
    return evs


def test_tracer_chrome_format(tmp_path):
    tr = Tracer()
    t0 = tr.now_us()
    tr.instant("admission", "req0", {"prompt_len": 16})
    tr.complete("dispatch", "decode", t0, {"k_steps": 8})
    tr.counter("tokens", {"emitted": 5})
    tr.instant("admission", "req1")
    tr.complete("dispatch", "decode", tr.now_us())
    evs = _check_chrome_trace(tr.to_dict())
    assert sum(ev["ph"] == "X" for ev in evs) == 2
    assert sum(ev["ph"] == "i" for ev in evs) == 2
    assert sum(ev["ph"] == "C" for ev in evs) == 1
    # the two admission events share one track, dispatch another
    tracks = {ev["args"]["name"] for ev in evs
              if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert tracks == {"admission", "dispatch"}
    path = tmp_path / "trace.json"
    tr.save(path)
    assert json.loads(path.read_text()) == tr.to_dict()


# ---------------------------------------------------------------------------
# Engine integration: counters surface, conservation, zero-cost contract
# ---------------------------------------------------------------------------

def test_serve_metrics_free_on_hot_path(tmp_path):
    """Metrics+tracer on vs off: identical tokens, identical host syncs,
    identical jit cache sizes — and the device counters balance."""
    cfg, model, params, spec = _setup()
    common = sample_batch(jax.random.PRNGKey(7), spec, 1, 16)[0]
    import jax.numpy as jnp
    prompts = [jnp.concatenate(
        [common, sample_batch(jax.random.PRNGKey(50 + i), spec, 1, 8)[0]])
        for i in range(4)]
    gen = 6

    def mk(**kw):
        return Engine(model, params, slots=2, cache_len=48, k_steps=4,
                      paged=True, block_size=8, prefix_cache=True,
                      check_invariants=True, **kw)

    reg, tr = MetricsRegistry(), Tracer()
    e_on = mk(metrics=reg, tracer=tr)
    e_off = mk()
    outs_on, st_on = e_on.serve(prompts, gen_tokens=gen, return_stats=True)
    outs_off, st_off = e_off.serve(prompts, gen_tokens=gen,
                                   return_stats=True)
    assert outs_on == outs_off
    assert st_on["host_syncs"] == st_off["host_syncs"]
    assert e_on.compile_counts() == e_off.compile_counts()

    # device counters: surfaced, identical on/off, and conserved
    c = st_on["counters"]
    assert set(c) == set(COUNTER_KEYS)
    assert c == st_off["counters"]
    # chunked path: every token emits through the dispatch grid
    assert c["tokens"] == st_on["tokens"]
    assert c["chunks_completed"] == len(prompts)
    assert c["prefix_hit_tokens"] == st_on["prefix_hits"]
    # popped == released + live; after drain only index holds live
    assert (c["blocks_popped"] - c["blocks_released"]
            == len(e_on._hold_blocks))
    assert c["drafted"] == c["accepted"] + c["rejected"] == 0  # no spec

    # lifecycle metrics landed with the required fields
    snap = reg.snapshot()
    assert snap["counters"]["requests.completed"] == len(prompts)
    assert snap["counters"]["device.tokens"] == c["tokens"]
    for h in ("request.ttft_s", "request.tpot_s", "request.queue_wait_s",
              "request.prompt_len", "request.gen_len",
              "request.prefix_hit_frac"):
        assert snap["histograms"][h]["count"] == len(prompts)
        assert snap["histograms"][h]["p50"] is not None
    for g in ("alloc.live_blocks", "alloc.free_blocks",
              "alloc.index_holds", "alloc.ledger_headroom"):
        assert snap["gauges"][g] is not None
    assert snap["gauges"]["alloc.live_blocks"] == len(e_on._hold_blocks)
    assert "spec.acceptance_rate" not in snap["gauges"]  # non-spec run

    # the engine-produced trace is schema-valid with the engine tracks
    evs = _check_chrome_trace(tr.to_dict())
    tracks = {ev["args"]["name"] for ev in evs
              if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert {"admission", "dispatch", "prefill-chunk"} <= tracks
    assert any(ev["ph"] == "C" for ev in evs)

    # warm second serve: counters re-zero, conservation re-baselines on
    # the now-held index blocks
    outs2, st2 = e_on.serve(prompts, gen_tokens=gen, return_stats=True)
    assert outs2 == outs_on                          # warm token exactness
    c2 = st2["counters"]
    assert (c2["blocks_popped"] - c2["blocks_released"]
            + st_on["counters"]["blocks_popped"]
            - st_on["counters"]["blocks_released"]
            == len(e_on._hold_blocks))
    assert c2["prefix_hit_tokens"] == st2["prefix_hits"]
    assert c2["prefix_hit_tokens"] > c["prefix_hit_tokens"]  # warm hits
