"""Training-substrate tests: descent, checkpoint/restart, microbatch
equivalence, 8-bit Adam, gradient compression."""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_arch, reduced
from repro.data import LanguageSpec, train_batch
from repro.launch.steps import init_train_state, make_train_step
from repro.models import build_model

CFG = reduced(get_arch("glm4-9b"))
TC = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=60)
SPEC = LanguageSpec(vocab=CFG.vocab_size)


def _run(tc, steps=25, batch=4, seq=64, state=None):
    model = build_model(CFG)
    step = jax.jit(make_train_step(model, tc))
    if state is None:
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
    losses = []
    for t in range(steps):
        b = train_batch(SPEC, tc.seed, t, batch, seq)
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def test_loss_descends():
    _, losses = _run(TC, steps=40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3]


def test_microbatch_equivalent():
    """Gradient accumulation: same trajectory as the monolithic batch."""
    tc1 = TC
    tc2 = dataclasses.replace(TC, microbatch=4)
    s1, l1 = _run(tc1, steps=6, batch=8)
    s2, l2 = _run(tc2, steps=6, batch=8)
    np.testing.assert_allclose(l1, l2, rtol=2e-2)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_int8_adam_tracks_fp32():
    tc8 = dataclasses.replace(TC, opt_state_dtype="int8")
    _, l32 = _run(TC, steps=25)
    _, l8 = _run(tc8, steps=25)
    # same descent behaviour within quantization slack
    assert abs(np.mean(l8[-5:]) - np.mean(l32[-5:])) < 0.4, (l8[-3:], l32[-3:])


def test_grad_compression_error_feedback():
    """int8 EF compression still trains; the error state is nonzero."""
    tc = dataclasses.replace(TC, grad_compress="int8_ef")
    state, losses = _run(tc, steps=40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.25
    err_norm = sum(float(jnp.sum(jnp.abs(e)))
                   for e in jax.tree.leaves(state["err"]))
    assert err_norm > 0.0


def test_compress_roundtrip_bias_free():
    """EF invariant: residual carries exactly what compression dropped."""
    from repro.optim import compress_grads, init_error_state
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (37, 53))}
    err = init_error_state(g)
    gq, err2 = compress_grads(g, err)
    np.testing.assert_allclose(
        np.asarray(gq["w"] + err2["w"]), np.asarray(g["w"]),
        rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip_and_restart(tmp_path):
    from repro import checkpoint as ckpt
    model = build_model(CFG)
    tc = TC
    step = jax.jit(make_train_step(model, tc))
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    for t in range(4):
        state, _ = step(state, train_batch(SPEC, 0, t, 4, 64))
    d = str(tmp_path / "ck")
    ckpt.save(d, 4, state, keep_last=2)
    # restore into abstract shapes, continue, compare against uninterrupted
    shape = jax.eval_shape(lambda k: init_train_state(model, tc, k),
                           jax.random.PRNGKey(0))
    restored = ckpt.restore(d, 4, shape)
    s_a, s_b = state, restored
    for t in range(4, 7):
        b = train_batch(SPEC, 0, t, 4, 64)
        s_a, ma = step(s_a, b)
        s_b, mb = step(s_b, b)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]),
                               rtol=1e-5)


def test_checkpoint_gc_and_latest(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6.0)}
    for s in (10, 20, 30, 40):
        ckpt.save(d, s, tree, keep_last=2)
    assert ckpt.all_steps(d) == [30, 40]
    assert ckpt.latest(d) == 40


def test_checkpoint_corruption_detected(tmp_path):
    from repro import checkpoint as ckpt
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(8.0)}
    ckpt.save(d, 1, tree)
    fn = os.path.join(d, "step_00000001", "a.npy")
    with open(fn, "ab") as f:
        f.write(b"junk")
    with pytest.raises(IOError):
        ckpt.restore(d, 1, tree)


def test_restart_loop_recovers(tmp_path):
    """train_loop survives an injected step failure (fault tolerance)."""
    from repro.launch import train as T
    model = build_model(CFG)
    tc = dataclasses.replace(TC, total_steps=10)
    calls = {"n": 0}
    orig = T.train_batch

    def flaky(spec, seed, step, batch, seq, **kw):
        calls["n"] += 1
        if calls["n"] == 7:
            raise RuntimeError("injected node failure")
        return orig(spec, seed, step, batch, seq, **kw)

    T.train_batch = flaky
    try:
        out = T.train_loop(model, tc, batch_size=4, seq=64, steps=10,
                           ckpt_dir=str(tmp_path / "ck"), save_every=3,
                           log_every=100)
    finally:
        T.train_batch = orig
    assert "state" in out  # completed despite the injected failure
